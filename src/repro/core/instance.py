"""Instance interface: the bridge between a targeted layer and its stage
(paper §4.1).

``Instance.enforce`` intercepts a request, builds the ``Context`` (picking up
propagated request-context and tenant), submits both to the stage, and returns
the enforced result to the original data path.

Layer-oriented facades are provided so instrumentation is a one-line change
(paper: "users only need to replace the original call for a PAIO one"):

* ``PosixInstance`` — read/write/open/close/fsync wrappers over file objects,
* ``KVInstance`` — put/get/delete wrappers,
* ``ArrayInstance`` — numpy-array reads/writes (the training-framework layer:
  input-pipeline fetches and checkpoint shard writes).
"""
from __future__ import annotations

import threading
from typing import Any, BinaryIO, Callable, List, Optional, Sequence

import numpy as np

from .context import Context, RequestType, build_context, current_context, current_tenant
from .objects import Result
from .stage import Stage


class Instance:
    """Generic instance: wraps a stage; builds contexts on the hot path."""

    __slots__ = ("stage", "_workflow_of")

    def __init__(self, stage: Stage, workflow_of: Optional[Callable[[], int]] = None) -> None:
        self.stage = stage
        self._workflow_of = workflow_of or threading.get_ident

    def enforce(
        self,
        request_type: int,
        size: int = 0,
        request: Any = None,
        request_context: Optional[str] = None,
        workflow_id: Optional[int] = None,
    ) -> Result:
        ctx = build_context(
            request_type,
            size=size,
            workflow_id=self._workflow_of() if workflow_id is None else workflow_id,
            request_context=request_context,
        )
        return self.stage.enforce(ctx, request)

    def enforce_ctx(self, ctx: Context, request: Any = None) -> Result:
        return self.stage.enforce(ctx, request)

    # -- batch submit API (batched data plane) ---------------------------
    def enforce_batch(
        self,
        request_type: int,
        sizes: Sequence[int],
        requests: Optional[Sequence[Any]] = None,
        request_context: Optional[str] = None,
        workflow_id: Optional[int] = None,
    ) -> List[Result]:
        """Submit a whole batch of same-type requests through the stage.

        Propagated request-context/tenant are sampled once per batch (all
        requests originate from this call site), contexts are built in one
        pass, and the stage routes/enforces the batch with amortized cost.
        """
        wf = self._workflow_of() if workflow_id is None else workflow_id
        rc = current_context() if request_context is None else request_context
        tenant = current_tenant()
        ctxs = [Context(wf, request_type, s, rc, tenant) for s in sizes]
        return self.stage.enforce_batch(ctxs, requests)

    def enforce_ctx_batch(
        self, ctxs: Sequence[Context], requests: Optional[Sequence[Any]] = None
    ) -> List[Result]:
        return self.stage.enforce_batch(ctxs, requests)


class PosixInstance(Instance):
    """POSIX-like facade (paper §4.1: layer-oriented interfaces)."""

    def read(self, fobj: BinaryIO, n: int) -> bytes:
        self.enforce(RequestType.read, size=n)
        return fobj.read(n)

    def pread(self, fobj: BinaryIO, n: int, offset: int) -> bytes:
        self.enforce(RequestType.read, size=n)
        fobj.seek(offset)
        return fobj.read(n)

    def write(self, fobj: BinaryIO, buf: bytes) -> int:
        result = self.enforce(RequestType.write, size=len(buf), request=buf)
        payload = result.content if result.content is not None else buf
        return fobj.write(payload)

    def open(self, path: str, mode: str = "rb") -> BinaryIO:
        self.enforce(RequestType.open, size=0)
        return open(path, mode)

    def close(self, fobj: BinaryIO) -> None:
        self.enforce(RequestType.close, size=0)
        fobj.close()

    def fsync(self, fobj: BinaryIO) -> None:
        import os

        self.enforce(RequestType.fsync, size=0)
        os.fsync(fobj.fileno())


class KVInstance(Instance):
    """Key-value facade: enforcement around a backing dict-like store."""

    def put(self, store, key, value) -> None:
        size = len(value) if hasattr(value, "__len__") else 0
        self.enforce(RequestType.put, size=size, request=value)
        store[key] = value

    def get(self, store, key):
        value = store.get(key)
        size = len(value) if value is not None and hasattr(value, "__len__") else 0
        self.enforce(RequestType.get, size=size)
        return value

    def delete(self, store, key) -> None:
        self.enforce(RequestType.delete, size=0)
        store.pop(key, None)


class ArrayInstance(Instance):
    """Training-framework facade: enforce around ndarray I/O.

    ``on_read``/``on_write`` wrap a producing/consuming thunk so the byte count
    is known to the stage; transformations installed on the channel (compress,
    quantize, checksum) are applied to the payload on writes.
    """

    def on_read(self, nbytes: int, thunk: Callable[[], np.ndarray]) -> np.ndarray:
        self.enforce(RequestType.read, size=nbytes)
        return thunk()

    def on_write(self, array: np.ndarray, sink: Callable[[Any], None]) -> Result:
        result = self.enforce(RequestType.write, size=array.nbytes, request=array)
        sink(result.content if result.content is not None else array)
        return result

    # -- batch submit (batched data plane) --------------------------------
    def on_read_batch(
        self, nbytes: Sequence[int], thunks: Sequence[Callable[[], np.ndarray]]
    ) -> List[np.ndarray]:
        """Admit a whole read burst through ``enforce_batch`` (one routing /
        stats / rate-limit pass), then materialize the payloads."""
        self.enforce_batch(RequestType.read, list(nbytes))
        return [t() for t in thunks]

    def on_write_batch(
        self,
        arrays: Sequence[np.ndarray],
        sink: Callable[[int, Any], None],
    ) -> List[Result]:
        """Batch twin of ``on_write``: all arrays are enforced in one
        ``enforce_batch`` pass (transformations installed on the channel run
        their fused batch paths — e.g. one quantize kernel call for the whole
        burst), then ``sink(i, payload)`` receives each enforced payload in
        submission order."""
        results = self.enforce_batch(
            RequestType.write, [a.nbytes for a in arrays], list(arrays)
        )
        for i, (r, a) in enumerate(zip(results, arrays)):
            sink(i, r.content if r.content is not None else a)
        return results
