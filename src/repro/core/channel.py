"""Channel: the stream-like abstraction requests flow through (paper §3.1).

A channel owns one or more enforcement objects plus the rule that maps a
request's context to the object that must service it (``select_object``,
paper Fig 3 step 4), and per-workflow statistics counters (§4.3).

The hot path is: object lookup (murmur token over the configured classifier
masks) → ``obj_enf`` → stats record. Locking: the routing table is swapped
atomically on rule installation (read-mostly, copy-on-write), so the hot path
takes no lock besides the stats counter's.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .clock import Clock, DEFAULT_CLOCK
from .context import Context
from .hashing import token_for
from .objects import EnforcementObject, Noop, Result
from .stats import ChannelStats, StatsSnapshot

DEFAULT_OBJECT_ID = "0"


def group_dispatch(
    n: int,
    groups: Dict[str, List[int]],
    ctxs: Sequence[Context],
    requests: Optional[Sequence[Any]],
    call,
) -> List[Result]:
    """Shared scatter/gather for batched enforcement: for each routing group,
    slice out its contexts/requests, run ``call(key, sub_ctxs, sub_requests)``
    and scatter the Results back into submission order. Used by both the
    stage (group = channel) and the channel (group = enforcement object) so
    the batch ≡ sequential contract lives in one place."""
    results: List[Optional[Result]] = [None] * n
    for key, idxs in groups.items():
        sub_ctx = [ctxs[i] for i in idxs]
        sub_req = None if requests is None else [requests[i] for i in idxs]
        for i, r in zip(idxs, call(key, sub_ctx, sub_req)):
            results[i] = r
    return results  # type: ignore[return-value]


class Channel:
    def __init__(self, name: str, clock: Clock = DEFAULT_CLOCK) -> None:
        self.name = name
        self._clock = clock
        self._objects: Dict[str, EnforcementObject] = {DEFAULT_OBJECT_ID: Noop()}
        # ordered (mask, {token: object_id}) — most specific masks first
        self._routing: List[Tuple[Tuple[str, ...], Dict[int, str]]] = []
        #: classifier-tuple → resolved object id (§Perf iteration 1 memo)
        self._route_cache: Dict[tuple, str] = {}
        self._mutate = threading.Lock()
        self.stats = ChannelStats(name, clock)
        #: §Perf S2: in-flight tracking matters only when an object can block
        #: (DRL/priority); noop/transform channels keep a single-lock fast path
        self._track_inflight = False

    # -- housekeeping ------------------------------------------------------
    def add_object(self, object_id: str, obj: EnforcementObject) -> None:
        with self._mutate:
            self._objects = {**self._objects, object_id: obj}
            if obj.kind in ("drl", "priority_gate"):
                self._track_inflight = True

    def remove_object(self, object_id: str) -> None:
        with self._mutate:
            objs = dict(self._objects)
            objs.pop(object_id, None)
            self._objects = objs

    def get_object(self, object_id: str) -> Optional[EnforcementObject]:
        return self._objects.get(object_id)

    def object_ids(self) -> List[str]:
        return list(self._objects.keys())

    # -- differentiation ----------------------------------------------------
    def add_object_route(self, mask: Tuple[str, ...], key: Tuple[Any, ...], object_id: str) -> None:
        """Install a select_object mapping: requests whose classifiers under
        ``mask`` hash to ``token_for(key)`` are serviced by ``object_id``."""
        with self._mutate:
            routing = [(m, dict(t)) for m, t in self._routing]
            for m, table in routing:
                if m == mask:
                    table[token_for(key)] = object_id
                    break
            else:
                routing.append((mask, {token_for(key): object_id}))
            routing.sort(key=lambda e: -len(e[0]))
            self._routing = routing
            self._route_cache = {}

    def select_object(self, ctx: Context) -> str:
        if not self._routing:
            return DEFAULT_OBJECT_ID
        key = (ctx.workflow_id, ctx.request_type, ctx.request_context, ctx.tenant)
        cached = self._route_cache.get(key)
        if cached is not None:
            return cached
        oid = DEFAULT_OBJECT_ID
        for mask, table in self._routing:
            token = token_for(tuple(getattr(ctx, c) for c in mask))
            hit = table.get(token)
            if hit is not None:
                oid = hit
                break
        if len(self._route_cache) < 65536:
            self._route_cache[key] = oid
        return oid

    # -- enforcement (hot path) ---------------------------------------------
    def enforce(self, ctx: Context, request: Any = None) -> Result:
        oid = self.select_object(ctx)
        obj = self._objects.get(oid)
        if obj is None:  # object removed concurrently — fall back to noop
            obj = self._objects[DEFAULT_OBJECT_ID]
        if self._track_inflight:
            self.stats.begin_op()
        result = obj.obj_enf(ctx, request)
        self.stats.record(ctx.size)
        return result

    def enforce_batch(
        self,
        ctxs: Sequence[Context],
        requests: Optional[Sequence[Any]] = None,
        _homogeneous: Optional[bool] = None,
    ) -> List[Result]:
        """Batch twin of ``enforce``: resolve objects for the whole batch,
        dispatch ONE ``obj_enf_batch`` call per group, and register stats with
        one lock acquisition. Elementwise equivalent to sequential ``enforce``
        (same routing, same Results, same stats totals). ``_homogeneous`` lets
        the stage pass down an already-computed all-same-context fact.
        """
        n = len(ctxs)
        if n == 0:
            return []
        default = self._objects[DEFAULT_OBJECT_ID]
        if self._track_inflight:
            self.stats.begin_ops(n)
        c0 = ctxs[0]
        homogeneous = all(c is c0 for c in ctxs) if _homogeneous is None else _homogeneous
        if not self._routing:
            results = default.obj_enf_batch(ctxs, requests)
        elif homogeneous:  # homogeneous submit loop fast path
            obj = self._objects.get(self.select_object(c0)) or default
            results = obj.obj_enf_batch(ctxs, requests)
        else:
            groups: Dict[str, List[int]] = {}
            for i, c in enumerate(ctxs):
                groups.setdefault(self.select_object(c), []).append(i)
            if len(groups) == 1:
                oid = next(iter(groups))
                obj = self._objects.get(oid) or default
                results = obj.obj_enf_batch(ctxs, requests)
            else:
                results = group_dispatch(
                    n,
                    groups,
                    ctxs,
                    requests,
                    lambda oid, sc, sr: (self._objects.get(oid) or default).obj_enf_batch(sc, sr),
                )
        self.stats.record_batch(n, c0.size * n if homogeneous else sum(c.size for c in ctxs))
        return results

    # -- control ------------------------------------------------------------
    def configure_object(self, object_id: str, state: Dict[str, Any]) -> bool:
        obj = self._objects.get(object_id)
        if obj is None:
            return False
        obj.obj_config(state)
        return True

    def collect(self) -> StatsSnapshot:
        return self.stats.collect()

    def describe(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "objects": {oid: obj.describe() for oid, obj in self._objects.items()},
            "routes": [
                {"mask": list(mask), "entries": len(table)} for mask, table in self._routing
            ],
        }
