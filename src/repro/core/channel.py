"""Channel: the stream-like abstraction requests flow through (paper §3.1).

A channel owns one or more enforcement objects plus the rule that maps a
request's context to the object that must service it (``select_object``,
paper Fig 3 step 4), and per-workflow statistics counters (§4.3).

The hot path is: object lookup (murmur token over the configured classifier
masks) → ``obj_enf`` → stats record. Locking: the routing table is swapped
atomically on rule installation (read-mostly, copy-on-write), so the hot path
takes no lock besides the stats counter's.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .clock import Clock, DEFAULT_CLOCK
from .context import Context
from .hashing import token_for
from .objects import EnforcementObject, Noop, Result
from .stats import ChannelStats, StatsSnapshot

DEFAULT_OBJECT_ID = "0"

#: object kinds known to never impose scheduling waits; channels holding only
#: these skip the per-batch wait summation (~67 ns/op at batch 256). Any other
#: kind — including custom EnforcementObjects — is assumed to block, so its
#: wait telemetry stays batch ≡ sequential.
NONBLOCKING_KINDS = frozenset({"noop", "checksum", "compress", "decompress", "quantize_int8"})


def routing_without(
    routing: List[Tuple[Tuple[str, ...], Dict[int, str]]],
    mask: Tuple[str, ...],
    token: int,
) -> Tuple[List[Tuple[Tuple[str, ...], Dict[int, str]]], bool]:
    """Copy-on-write removal of one ``(mask, token)`` routing entry.

    Shared by the stage (request→channel) and channel (request→object)
    teardown paths so the rebuild-minus-one-token contract — drop emptied
    mask levels, preserve specificity order — lives in one place. Returns
    ``(new_routing, removed)``.
    """
    out: List[Tuple[Tuple[str, ...], Dict[int, str]]] = []
    removed = False
    for m, table in routing:
        t = dict(table)
        if m == mask and token in t:
            del t[token]
            removed = True
        if t:
            out.append((m, t))
    return out, removed


def group_dispatch(
    n: int,
    groups: Dict[str, List[int]],
    ctxs: Sequence[Context],
    requests: Optional[Sequence[Any]],
    call,
) -> List[Result]:
    """Shared scatter/gather for batched enforcement: for each routing group,
    slice out its contexts/requests, run ``call(key, sub_ctxs, sub_requests)``
    and scatter the Results back into submission order. Used by both the
    stage (group = channel) and the channel (group = enforcement object) so
    the batch ≡ sequential contract lives in one place."""
    results: List[Optional[Result]] = [None] * n
    for key, idxs in groups.items():
        sub_ctx = [ctxs[i] for i in idxs]
        sub_req = None if requests is None else [requests[i] for i in idxs]
        for i, r in zip(idxs, call(key, sub_ctx, sub_req)):
            results[i] = r
    return results  # type: ignore[return-value]


def _wants_observe(flt: Any) -> bool:
    """Does this filter override ``observe``? Pre-computed at install so the
    enforce hot path never pays per-request no-op observe calls."""
    observe = getattr(type(flt), "observe", None)
    if observe is None:
        return False
    from repro.filters.registry import Filter  # local: core stays cycle-free

    return not isinstance(flt, Filter) or observe is not Filter.observe


class Channel:
    def __init__(self, name: str, clock: Clock = DEFAULT_CLOCK) -> None:
        self.name = name
        self._clock = clock
        self._objects: Dict[str, EnforcementObject] = {DEFAULT_OBJECT_ID: Noop()}
        # ordered (mask, {token: object_id}) — most specific masks first
        self._routing: List[Tuple[Tuple[str, ...], Dict[int, str]]] = []
        #: classifier-tuple → resolved object id (§Perf iteration 1 memo)
        self._route_cache: Dict[tuple, str] = {}
        self._mutate = threading.Lock()
        self.stats = ChannelStats(name, clock)
        #: §Perf S2: in-flight tracking matters only when an object can block
        #: (DRL/priority); noop/transform channels keep a single-lock fast path
        self._track_inflight = False
        #: wait summation needed once any possibly-blocking object is present
        self._track_wait = False
        #: installed filter chain: ``(filter_id, filter, wants_observe)`` in
        #: install order, swapped copy-on-write like the routing table — the
        #: hot path reads it with a single attribute load, no lock
        self._filters: Tuple[Tuple[str, Any, bool], ...] = ()

    # -- housekeeping ------------------------------------------------------
    def add_object(self, object_id: str, obj: EnforcementObject) -> None:
        with self._mutate:
            self._objects = {**self._objects, object_id: obj}
            if obj.kind in ("drl", "priority_gate"):
                self._track_inflight = True
            if obj.kind not in NONBLOCKING_KINDS:
                self._track_wait = True

    def remove_object(self, object_id: str) -> None:
        """Remove an enforcement object. The default object id always stays
        populated — removing it resets the slot to a pass-through Noop (the
        enforce paths read it unconditionally as the fallback), it never
        leaves a hole."""
        with self._mutate:
            objs = dict(self._objects)
            objs.pop(object_id, None)
            if object_id == DEFAULT_OBJECT_ID:
                objs[DEFAULT_OBJECT_ID] = Noop()
            self._objects = objs

    def get_object(self, object_id: str) -> Optional[EnforcementObject]:
        return self._objects.get(object_id)

    def object_ids(self) -> List[str]:
        return list(self._objects.keys())

    # -- filters (runtime-installable, repro.filters) ------------------------
    def install_filter(self, filter_id: str, flt: Any) -> None:
        """Install (or atomically replace) a filter in this channel's chain.

        Filters wrap object dispatch: every enforced request's result flows
        through the chain in install order. Re-installing an existing
        ``filter_id`` swaps the instance in place, keeping its chain
        position — an in-flight request sees either the old or the new
        filter, never a gap.
        """
        wants_observe = _wants_observe(flt)
        with self._mutate:
            chain = list(self._filters)
            for i, entry in enumerate(chain):
                if entry[0] == filter_id:
                    chain[i] = (filter_id, flt, wants_observe)
                    break
            else:
                chain.append((filter_id, flt, wants_observe))
            self._filters = tuple(chain)

    def remove_filter(self, filter_id: str) -> bool:
        with self._mutate:
            chain = tuple(e for e in self._filters if e[0] != filter_id)
            removed = len(chain) != len(self._filters)
            self._filters = chain
        return removed

    def get_filter(self, filter_id: str) -> Optional[Any]:
        for fid, flt, _ in self._filters:
            if fid == filter_id:
                return flt
        return None

    def filter_ids(self) -> List[str]:
        return [fid for fid, _, _ in self._filters]

    def configure_filter(self, filter_id: str, state: Dict[str, Any]) -> bool:
        flt = self.get_filter(filter_id)
        if flt is None:
            return False
        flt.obj_config(state)
        return True

    # -- differentiation ----------------------------------------------------
    def add_object_route(self, mask: Tuple[str, ...], key: Tuple[Any, ...], object_id: str) -> None:
        """Install a select_object mapping: requests whose classifiers under
        ``mask`` hash to ``token_for(key)`` are serviced by ``object_id``."""
        with self._mutate:
            routing = [(m, dict(t)) for m, t in self._routing]
            for m, table in routing:
                if m == mask:
                    table[token_for(key)] = object_id
                    break
            else:
                routing.append((mask, {token_for(key): object_id}))
            routing.sort(key=lambda e: -len(e[0]))
            self._routing = routing
            self._route_cache = {}

    def remove_object_route(self, mask: Tuple[str, ...], key: Tuple[Any, ...]) -> bool:
        """Uninstall one request→object mapping (policy teardown path)."""
        with self._mutate:
            self._routing, removed = routing_without(self._routing, mask, token_for(key))
            self._route_cache = {}
        return removed

    def select_object(self, ctx: Context) -> str:
        if not self._routing:
            return DEFAULT_OBJECT_ID
        key = (ctx.workflow_id, ctx.request_type, ctx.request_context, ctx.tenant)
        cached = self._route_cache.get(key)
        if cached is not None:
            return cached
        oid = DEFAULT_OBJECT_ID
        for mask, table in self._routing:
            token = token_for(tuple(getattr(ctx, c) for c in mask))
            hit = table.get(token)
            if hit is not None:
                oid = hit
                break
        if len(self._route_cache) < 65536:
            self._route_cache[key] = oid
        return oid

    # -- enforcement (hot path) ---------------------------------------------
    def enforce(self, ctx: Context, request: Any = None) -> Result:
        oid = self.select_object(ctx)
        obj = self._objects.get(oid)
        if obj is None:  # object removed concurrently — fall back to noop
            obj = self._objects[DEFAULT_OBJECT_ID]
        if self._track_inflight:
            self.stats.begin_op()
        result = obj.obj_enf(ctx, request)
        filters = self._filters
        if filters:
            enf_wait = result.wait_seconds
            for _fid, flt, wants_observe in filters:
                fres = flt.obj_enf(ctx, result.content)
                result.content = fres.content
                if fres.wait_seconds:
                    result.wait_seconds += fres.wait_seconds
                if fres.meta:
                    result.meta = {**result.meta, **fres.meta} if result.meta else fres.meta
                if wants_observe:
                    flt.observe(ctx, enf_wait)
        self.stats.record(ctx.size, result.wait_seconds)
        return result

    def enforce_batch(
        self,
        ctxs: Sequence[Context],
        requests: Optional[Sequence[Any]] = None,
        _homogeneous: Optional[bool] = None,
    ) -> List[Result]:
        """Batch twin of ``enforce``: resolve objects for the whole batch,
        dispatch ONE ``obj_enf_batch`` call per group, and register stats with
        one lock acquisition. Elementwise equivalent to sequential ``enforce``
        (same routing, same Results, same stats totals). ``_homogeneous`` lets
        the stage pass down an already-computed all-same-context fact.
        """
        n = len(ctxs)
        if n == 0:
            return []
        default = self._objects[DEFAULT_OBJECT_ID]
        if self._track_inflight:
            self.stats.begin_ops(n)
        c0 = ctxs[0]
        homogeneous = all(c is c0 for c in ctxs) if _homogeneous is None else _homogeneous
        if not self._routing:
            results = default.obj_enf_batch(ctxs, requests)
        elif homogeneous:  # homogeneous submit loop fast path
            obj = self._objects.get(self.select_object(c0)) or default
            results = obj.obj_enf_batch(ctxs, requests)
        else:
            groups: Dict[str, List[int]] = {}
            for i, c in enumerate(ctxs):
                groups.setdefault(self.select_object(c), []).append(i)
            if len(groups) == 1:
                oid = next(iter(groups))
                obj = self._objects.get(oid) or default
                results = obj.obj_enf_batch(ctxs, requests)
            else:
                results = group_dispatch(
                    n,
                    groups,
                    ctxs,
                    requests,
                    lambda oid, sc, sr: (self._objects.get(oid) or default).obj_enf_batch(sc, sr),
                )
        if self._filters:
            self._apply_filters_batch(ctxs, results)
        # gated on kind, not on the drl/priority allowlist: any object whose
        # kind is not known non-blocking feeds wait telemetry identically
        # batch vs sequential — per-op waits, so the histogram sees the same
        # distribution either way — while noop/transform batches skip the O(n) pass
        nbytes = c0.size * n if homogeneous else sum(c.size for c in ctxs)
        if self._track_wait:
            self.stats.record_batch(n, nbytes, waits=[r.wait_seconds for r in results])
        else:
            self.stats.record_batch(n, nbytes)
        return results

    def _apply_filters_batch(self, ctxs: Sequence[Context], results: List[Result]) -> None:
        """Run the filter chain over a whole batch in place: one
        ``obj_enf_batch`` per filter, elementwise equivalent to the
        sequential ``enforce`` chain (same contents, waits, meta)."""
        # snapshot the enforcement waits BEFORE the chain runs, as the
        # sequential path does — observers see object-imposed delay only
        enf_waits: Optional[List[float]] = None
        if any(entry[2] for entry in self._filters):
            enf_waits = [r.wait_seconds for r in results]
        for _fid, flt, wants_observe in self._filters:
            fres_list = flt.obj_enf_batch(ctxs, [r.content for r in results])
            for r, fres in zip(results, fres_list):
                r.content = fres.content
                if fres.wait_seconds:
                    r.wait_seconds += fres.wait_seconds
                if fres.meta:
                    r.meta = {**r.meta, **fres.meta} if r.meta else fres.meta
            if wants_observe:
                observe = flt.observe
                for ctx, w in zip(ctxs, enf_waits):
                    observe(ctx, w)

    # -- control ------------------------------------------------------------
    def configure_object(self, object_id: str, state: Dict[str, Any]) -> bool:
        obj = self._objects.get(object_id)
        if obj is None:
            return False
        obj.obj_config(state)
        return True

    def collect(self) -> StatsSnapshot:
        snap = self.stats.collect()
        filters = self._filters
        if filters:
            extras = snap.extras
            for _fid, flt, _ in filters:
                collect_extras = getattr(flt, "collect_extras", None)
                if collect_extras is None:
                    continue
                for k, v in collect_extras().items():
                    extras[k] = extras.get(k, 0.0) + v
        return snap

    def describe(self) -> Dict[str, Any]:
        out = {
            "name": self.name,
            "objects": {oid: obj.describe() for oid, obj in self._objects.items()},
            "routes": [
                {"mask": list(mask), "entries": len(table)} for mask, table in self._routing
            ],
        }
        if self._filters:
            out["filters"] = {fid: flt.describe() for fid, flt, _ in self._filters}
        return out
