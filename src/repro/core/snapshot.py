"""Crash-safe persistence of a stage's control-applied configuration.

A stage process that dies and restarts comes back with an empty policy set:
no channels, no enforcement objects, no routes — and until the control plane
notices, probes, re-admits and re-ships everything, the stage enforces
*nothing*. For a data plane whose whole point is that enforcement is always
on, that window is the failure mode.

:class:`StageConfigJournal` closes it. It tracks the stage's **configuration
state** — the minimal keyed set of control rules whose replay reconstructs
the stage — and persists it as a versioned JSON snapshot with an atomic
write-then-rename on every mutation. A restarted stage process replays the
snapshot into its fresh :class:`~repro.core.stage.Stage` *before* opening its
control socket (:class:`~repro.transport.server.StageServer` does this when
given ``snapshot_path=``), so enforcement is restored before the control
plane can even see the stage again.

State is keyed, not journaled verbatim: repeated enforcement retunes of the
same (channel, object) collapse to the latest one, and a remove deletes the
matching create (plus, for ``remove_channel``, everything scoped under the
channel) — the snapshot stays proportional to live configuration, not to
control-loop uptime. Replay order is key insertion order, which preserves the
original apply order of the surviving creates (channel before its objects
before its routes), with enforcement state retuned in place.

The snapshot ``version`` is monotonic per journal lifetime, restored from the
file on load — a restarted stage reports ``snapshot_version`` in
``stage_info()`` so the control plane's recovery path can tell "restored from
snapshot vN" from "came back empty" and reconcile instead of replaying from
zero.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

from .rules import (
    DifferentiationRule,
    EnforcementRule,
    HousekeepingRule,
    rule_from_wire,
)


def _freeze_match(match: Dict[str, Any]) -> Tuple:
    return tuple(sorted(match.items()))


def _config_key(rule: Any) -> Optional[Tuple]:
    """Identity of the configuration entry ``rule`` creates or retunes
    (None: the rule is a remove — handled separately — or not configuration).
    Mirrors the policy compiler's entity keying so the control plane and the
    stage snapshot agree on what an entity is."""
    if isinstance(rule, HousekeepingRule):
        if rule.op == "create_channel":
            return ("chan", rule.channel)
        if rule.op == "create_object":
            return ("obj", rule.channel, rule.object_id)
        if rule.op == "install_filter":
            # re-installs of the same slot collapse to the latest spec; the
            # remove_channel cascade (k[1] == channel) covers filters too
            return ("filter", rule.channel, rule.object_id)
        return None
    if isinstance(rule, DifferentiationRule):
        return ("route", rule.channel, _freeze_match(rule.match), rule.object_id)
    if isinstance(rule, EnforcementRule):
        return ("enf", rule.channel, rule.object_id)
    return None


def _remove_key(rule: Any) -> Optional[Tuple]:
    """Identity of the entry a remove rule deletes (mirror of _config_key)."""
    if isinstance(rule, HousekeepingRule):
        if rule.op == "remove_channel":
            return ("chan", rule.channel)
        if rule.op == "remove_object":
            return ("obj", rule.channel, rule.object_id)
        if rule.op == "remove_filter":
            return ("filter", rule.channel, rule.object_id)
        if rule.op == "remove_route":
            return (
                "route",
                rule.channel,
                _freeze_match(rule.params.get("match") or {}),
                rule.object_id,
            )
    return None


class StageConfigJournal:
    """Keyed, versioned, atomically-persisted stage configuration.

    Thread-safe: the stage server records from per-connection threads. Saves
    are synchronous (one small JSON file per mutation, tmp + ``os.replace``);
    there is no fsync — the contract is atomicity (a reader never sees a torn
    file), not durability against power loss, which is the right trade for a
    process-crash recovery path.
    """

    def __init__(self, path: str, stage: Optional[str] = None) -> None:
        self.path = path
        self.stage = stage
        self._lock = threading.Lock()
        self._entries: Dict[Tuple, Dict[str, Any]] = {}
        self._version = 0
        self._restored_version = 0
        if os.path.exists(path):
            self._load()

    # -- persistence ---------------------------------------------------------
    def _load(self) -> None:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            # a missing/torn snapshot (crash before the first rename) means
            # "no restored state", never a refusal to start
            return
        self._version = self._restored_version = int(doc.get("version", 0))  # paio: ignore[lock-discipline] -- _load runs only from __init__, before any concurrent reader can exist
        if doc.get("stage") and self.stage is None:
            self.stage = doc["stage"]
        for wire in doc.get("rules", []):
            rule = rule_from_wire(wire)
            key = _config_key(rule)
            if key is not None:
                self._entries[key] = wire

    def _save_locked(self) -> None:
        doc = {
            "version": self._version,
            "stage": self.stage,
            "rules": list(self._entries.values()),
        }
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, self.path)

    # -- recording -----------------------------------------------------------
    def record(self, rule: Any) -> None:
        """Fold one successfully-applied rule into the snapshot and persist.

        Creates/retunes upsert their entry (an existing key keeps its replay
        position — a retune must not reorder a create past its channel);
        removes delete the matching entry, ``remove_channel`` cascading to
        every object/route/enforcement entry scoped under the channel."""
        with self._lock:
            key = _config_key(rule)
            if key is not None:
                self._entries[key] = rule.to_wire()
            else:
                rkey = _remove_key(rule)
                if rkey is None:
                    return  # not configuration (unknown op): nothing to do
                self._entries.pop(rkey, None)
                if rkey[0] == "chan":
                    channel = rkey[1]
                    for k in [k for k in self._entries if k[1] == channel]:
                        del self._entries[k]
                elif rkey[0] == "obj":
                    # the object's enforcement state dies with it
                    self._entries.pop(("enf", rkey[1], rkey[2]), None)
            self._version += 1
            self._save_locked()

    # -- restore -------------------------------------------------------------
    def restore(self, stage: Any) -> int:
        """Replay the snapshot into ``stage`` (a fresh process's empty stage);
        returns the number of rules replayed. Replay is in original apply
        order; a rule the stage rejects is skipped (the control plane's
        recovery reconcile repairs any gap)."""
        with self._lock:
            wires = list(self._entries.values())
        replayed = 0
        for wire in wires:
            rule = rule_from_wire(wire)
            try:
                if isinstance(rule, HousekeepingRule):
                    ok = stage.hsk_rule(rule)
                elif isinstance(rule, DifferentiationRule):
                    ok = stage.dif_rule(rule)
                else:
                    ok = stage.enf_rule(rule)
            except Exception:  # noqa: BLE001 — restore is best-effort
                ok = False
            if ok:
                replayed += 1
        return replayed

    # -- introspection -------------------------------------------------------
    @property
    def version(self) -> int:
        with self._lock:
            return self._version

    @property
    def restored_version(self) -> int:
        """Version loaded from disk at construction (0: started empty)."""
        return self._restored_version

    def rules(self) -> List[Any]:
        """The current configuration as replayable rules (snapshot order)."""
        with self._lock:
            return [rule_from_wire(w) for w in self._entries.values()]

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


__all__ = ["StageConfigJournal"]
