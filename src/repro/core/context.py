"""Context: the request-classifier abstraction of PAIO (paper §3.1).

A ``Context`` is a metadata-like object generated per intercepted request. It
carries the *classifiers* used by the differentiation module: ``workflow_id``
(e.g. thread id), ``request_type`` (read/write/open/put/get/...), ``size`` in
bytes, and ``request_context`` — the layer-internal origin of the request
(foreground, bg_flush, bg_compaction_L0_L1, bg_checkpoint, ...), made available
through *context propagation*.

Context creation sits on the hot path (the paper measures ~17 ns); we keep it a
``__slots__`` class with no validation and provide a thread-local propagation
stack so instrumented layers can annotate their critical paths without plumbing
arguments through every call (paper §3.3 "Context propagation").
"""
from __future__ import annotations

import threading
from enum import IntEnum
from typing import Any, Optional


class RequestType(IntEnum):
    """I/O request verbs PAIO differentiates on (POSIX- and KV-level)."""

    no_op = 0
    read = 1
    write = 2
    open = 3
    close = 4
    put = 5
    get = 6
    delete = 7
    fsync = 8


#: Well-known request contexts. Free-form strings are also allowed — these are
#: the ones used by the paper's use cases plus the training-stack analogues.
FOREGROUND = "fg_task"
BG_FLUSH = "bg_flush"
BG_COMPACTION = "bg_compaction"
BG_COMPACTION_L0 = "bg_compaction_L0_L1"
BG_COMPACTION_HIGH = "bg_compaction_LN"
BG_CHECKPOINT = "bg_checkpoint"
BG_EVAL = "bg_eval"
BG_TRACE = "bg_trace"
FG_FETCH = "fg_fetch"
NO_CONTEXT = ""


class Context:
    """Per-request classifier bundle (paper §3.1, Table 1)."""

    __slots__ = ("workflow_id", "request_type", "size", "request_context", "tenant")

    def __init__(
        self,
        workflow_id: int,
        request_type: int = RequestType.no_op,
        size: int = 0,
        request_context: str = NO_CONTEXT,
        tenant: Optional[str] = None,
    ) -> None:
        self.workflow_id = workflow_id
        self.request_type = request_type
        self.size = size
        self.request_context = request_context
        self.tenant = tenant

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"Context(wf={self.workflow_id}, type={RequestType(self.request_type).name}, "
            f"size={self.size}, ctx={self.request_context!r}, tenant={self.tenant!r})"
        )

    def classifier_tuple(self) -> tuple:
        return (self.workflow_id, int(self.request_type), self.request_context)


class _PropagationState(threading.local):
    def __init__(self) -> None:
        self.stack: list[str] = []
        self.tenant: Optional[str] = None


_prop = _PropagationState()


class propagate_context:
    """Thread-local context propagation (paper §3.3).

    Instrumenting a layer's critical path is one ``with`` statement::

        with propagate_context(BG_FLUSH):
            ...   # every request intercepted below carries request_context=bg_flush

    Nested scopes shadow outer ones, mirroring how a compaction job can spawn
    finer-grained sub-contexts.
    """

    __slots__ = ("ctx",)

    def __init__(self, ctx: str) -> None:
        self.ctx = ctx

    def __enter__(self) -> "propagate_context":
        _prop.stack.append(self.ctx)
        return self

    def __exit__(self, *exc: Any) -> None:
        _prop.stack.pop()


class propagate_tenant:
    """Tenant annotation for multi-tenant serving / shared-storage scenarios."""

    __slots__ = ("tenant", "_prev")

    def __init__(self, tenant: str) -> None:
        self.tenant = tenant
        self._prev: Optional[str] = None

    def __enter__(self) -> "propagate_tenant":
        self._prev = _prop.tenant
        _prop.tenant = self.tenant
        return self

    def __exit__(self, *exc: Any) -> None:
        _prop.tenant = self._prev


def current_context() -> str:
    """The innermost propagated request context for this thread."""
    stack = _prop.stack
    return stack[-1] if stack else NO_CONTEXT


def current_tenant() -> Optional[str]:
    return _prop.tenant


def build_context(
    request_type: int,
    size: int = 0,
    workflow_id: Optional[int] = None,
    request_context: Optional[str] = None,
) -> Context:
    """Construct a Context picking up propagated state.

    ``workflow_id`` defaults to the calling thread's id — the paper treats each
    thread interacting with the next layer as a workflow (§5.1).
    """
    return Context(
        workflow_id=threading.get_ident() if workflow_id is None else workflow_id,
        request_type=request_type,
        size=size,
        request_context=current_context() if request_context is None else request_context,
        tenant=current_tenant(),
    )
