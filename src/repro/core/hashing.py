"""MurmurHash3 (x86 32-bit) — the paper's channel-token hashing scheme (§4.3).

PAIO concatenates a context's classifiers and hashes them into a fixed-size
token with MurmurHash3 to build the request→channel / request→enforcement-object
maps. We implement murmur3_32 exactly (validated against the reference vectors
of Appleby's SMHasher in tests) so differentiation tokens are stable across
processes — a requirement for rules sent by an *external* control plane to refer
to the same tokens the data plane computes.
"""
from __future__ import annotations

_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_MASK = 0xFFFFFFFF


def _rotl32(x: int, r: int) -> int:
    return ((x << r) | (x >> (32 - r))) & _MASK


def murmur3_32(data: bytes, seed: int = 0) -> int:
    """Pure-python MurmurHash3 x86_32."""
    length = len(data)
    h = seed & _MASK
    rounded = length & ~0x3
    for i in range(0, rounded, 4):
        k = int.from_bytes(data[i : i + 4], "little")
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
        h = _rotl32(h, 13)
        h = (h * 5 + 0xE6546B64) & _MASK
    # tail
    k = 0
    tail = data[rounded:]
    if len(tail) >= 3:
        k ^= tail[2] << 16
    if len(tail) >= 2:
        k ^= tail[1] << 8
    if len(tail) >= 1:
        k ^= tail[0]
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
    # finalization
    h ^= length
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _MASK
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _MASK
    h ^= h >> 16
    return h


def token_for(parts: tuple, seed: int = 0x5D5) -> int:
    """Differentiation token: concatenate classifiers, murmur-hash to 32 bits.

    ``parts`` is any tuple of ints/strings (a subset of Context classifiers as
    chosen by the stage's differentiation spec).
    """
    raw = "\x1f".join(str(p) for p in parts).encode("utf-8")
    return murmur3_32(raw, seed)


# --------------------------------------------------------------------------- #
# batched hashing (enforce_batch route resolution)                            #
# --------------------------------------------------------------------------- #
#: finalization / mixing constants kept as ints; batch math runs in uint64
#: with explicit 32-bit masking so numpy never silently widens or warns.
_FC1 = 0x85EBCA6B
_FC2 = 0xC2B2AE35


def murmur3_32_batch(datas, seed: int = 0):
    """Vectorized murmur3_32 over a list of byte strings.

    Bit-exact with :func:`murmur3_32` per row (asserted by tests). All rows are
    packed into one ``[N, W]`` little-endian word matrix; the body rounds run
    once per *word column* instead of once per word per request, so Python-level
    work is O(max_len/4) rather than O(total_bytes/4). Returns ``List[int]``.
    """
    import numpy as np

    n = len(datas)
    if n == 0:
        return []
    lengths = np.fromiter((len(d) for d in datas), dtype=np.int64, count=n)
    max_len = int(lengths.max())
    # +4 spare bytes so tail gathers never index past the row end
    width = ((max_len + 3) // 4) * 4 + 4
    buf = np.zeros((n, width), dtype=np.uint8)
    for i, d in enumerate(datas):
        if d:
            buf[i, : len(d)] = np.frombuffer(d, dtype=np.uint8)
    words = buf.view("<u4").astype(np.uint64)  # [N, width/4]

    h = np.full(n, seed & _MASK, dtype=np.uint64)
    n_body = lengths // 4  # full 4-byte words per row
    for j in range(int(n_body.max()) if n else 0):
        active = n_body > j
        k = (words[:, j] * _C1) & _MASK
        k = ((k << 15) | (k >> 17)) & _MASK
        k = (k * _C2) & _MASK
        hx = h ^ k
        hx = ((hx << 13) | (hx >> 19)) & _MASK
        hx = (hx * 5 + 0xE6546B64) & _MASK
        h = np.where(active, hx, h)

    # tails (1–3 trailing bytes), gathered per row
    tail_len = lengths & 3
    base = (lengths & ~3).astype(np.int64)
    rows = np.arange(n)
    b0 = buf[rows, base].astype(np.uint64)
    b1 = buf[rows, base + 1].astype(np.uint64)
    b2 = buf[rows, base + 2].astype(np.uint64)
    k = np.where(tail_len >= 3, b2 << 16, 0).astype(np.uint64)
    k = np.where(tail_len >= 2, k ^ (b1 << 8), k)
    k = np.where(tail_len >= 1, k ^ b0, k)
    k = (k * _C1) & _MASK
    k = ((k << 15) | (k >> 17)) & _MASK
    k = (k * _C2) & _MASK
    h = np.where(tail_len >= 1, h ^ k, h)

    # finalization (fmix32)
    h ^= lengths.astype(np.uint64)
    h ^= h >> 16
    h = (h * _FC1) & _MASK
    h ^= h >> 13
    h = (h * _FC2) & _MASK
    h ^= h >> 16
    return [int(x) for x in h]


def token_for_batch(parts_list, seed: int = 0x5D5):
    """Batched :func:`token_for`: one vectorized murmur pass over all rows.

    ``parts_list`` is a sequence of classifier tuples; returns ``List[int]``
    tokens, elementwise equal to ``[token_for(p, seed) for p in parts_list]``.
    """
    raws = ["\x1f".join(str(p) for p in parts).encode("utf-8") for parts in parts_list]
    return murmur3_32_batch(raws, seed)
