"""MurmurHash3 (x86 32-bit) — the paper's channel-token hashing scheme (§4.3).

PAIO concatenates a context's classifiers and hashes them into a fixed-size
token with MurmurHash3 to build the request→channel / request→enforcement-object
maps. We implement murmur3_32 exactly (validated against the reference vectors
of Appleby's SMHasher in tests) so differentiation tokens are stable across
processes — a requirement for rules sent by an *external* control plane to refer
to the same tokens the data plane computes.
"""
from __future__ import annotations

_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_MASK = 0xFFFFFFFF


def _rotl32(x: int, r: int) -> int:
    return ((x << r) | (x >> (32 - r))) & _MASK


def murmur3_32(data: bytes, seed: int = 0) -> int:
    """Pure-python MurmurHash3 x86_32."""
    length = len(data)
    h = seed & _MASK
    rounded = length & ~0x3
    for i in range(0, rounded, 4):
        k = int.from_bytes(data[i : i + 4], "little")
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
        h = _rotl32(h, 13)
        h = (h * 5 + 0xE6546B64) & _MASK
    # tail
    k = 0
    tail = data[rounded:]
    if len(tail) >= 3:
        k ^= tail[2] << 16
    if len(tail) >= 2:
        k ^= tail[1] << 8
    if len(tail) >= 1:
        k ^= tail[0]
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
    # finalization
    h ^= length
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _MASK
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _MASK
    h ^= h >> 16
    return h


# --------------------------------------------------------------------------- #
# fixed-width classifier packing                                              #
# --------------------------------------------------------------------------- #
# Classifiers are packed to fixed-width 8-byte binary codes before hashing
# (ints verbatim in two's complement; strings via a memoized 32-bit murmur,
# tagged so an int can never alias a string code). Fixed-width packing is what
# makes the *vectorized* batch tokenizer pay off: every row of a mask level
# has the same byte length, so the batch murmur runs with no per-row string
# building, no tail handling and no activity masking.
_U64 = (1 << 64) - 1
_STR_TAG = 1 << 63
_STR_SEED = 0x5F3759DF
#: memoized string → tagged code; classifier strings (request contexts,
#: tenants) are low-cardinality, so this is a one-time cost per distinct value
_STR_CODES: dict = {}


def _part_code(p) -> int:
    """8-byte code of one classifier part. Pure function of the value.

    Digit strings code as their integer value — the previous ``str(p)``-based
    hashing made ``"7"`` and ``7`` the same token, and wire clients (JSON
    rules from external controllers) rely on that looseness; the coercion is
    memoized so it costs one dict probe after the first sighting.
    """
    if type(p) is int:
        return p & _U64
    if isinstance(p, int) and not isinstance(p, bool):  # IntEnum etc.
        return int(p) & _U64
    s = p if type(p) is str else str(p)
    code = _STR_CODES.get(s)
    if code is None:
        # only canonical int spellings alias their integer ("7" ≡ 7); forms
        # like "01"/"007" keep their string identity — they were distinct
        # tokens under the old str-join hashing and must stay distinct
        if (s.isdigit() or (s[:1] == "-" and s[1:].isdigit())) and str(int(s)) == s:
            code = int(s) & _U64
        else:
            code = _STR_TAG | murmur3_32(s.encode("utf-8"), _STR_SEED)
        if len(_STR_CODES) < 65536:
            _STR_CODES[s] = code
    return code


def _pack(parts: tuple) -> bytes:
    return b"".join(_part_code(p).to_bytes(8, "little") for p in parts)


def token_for(parts: tuple, seed: int = 0x5D5) -> int:
    """Differentiation token: pack classifiers to fixed width, murmur to 32 bits.

    ``parts`` is any tuple of ints/strings (a subset of Context classifiers as
    chosen by the stage's differentiation spec).
    """
    return murmur3_32(_pack(parts), seed)


# --------------------------------------------------------------------------- #
# batched hashing (enforce_batch route resolution)                            #
# --------------------------------------------------------------------------- #
#: finalization / mixing constants kept as ints; batch math runs in uint64
#: with explicit 32-bit masking so numpy never silently widens or warns.
_FC1 = 0x85EBCA6B
_FC2 = 0xC2B2AE35


def murmur3_32_batch(datas, seed: int = 0):
    """Vectorized murmur3_32 over a list of byte strings.

    Bit-exact with :func:`murmur3_32` per row (asserted by tests). All rows are
    packed into one ``[N, W]`` little-endian word matrix; the body rounds run
    once per *word column* instead of once per word per request, so Python-level
    work is O(max_len/4) rather than O(total_bytes/4). Returns ``List[int]``.
    """
    import numpy as np

    n = len(datas)
    if n == 0:
        return []
    lengths = np.fromiter((len(d) for d in datas), dtype=np.int64, count=n)
    max_len = int(lengths.max())
    # +4 spare bytes so tail gathers never index past the row end
    width = ((max_len + 3) // 4) * 4 + 4
    buf = np.zeros((n, width), dtype=np.uint8)
    for i, d in enumerate(datas):
        if d:
            buf[i, : len(d)] = np.frombuffer(d, dtype=np.uint8)
    words = buf.view("<u4").astype(np.uint64)  # [N, width/4]

    h = np.full(n, seed & _MASK, dtype=np.uint64)
    n_body = lengths // 4  # full 4-byte words per row
    for j in range(int(n_body.max()) if n else 0):
        active = n_body > j
        k = (words[:, j] * _C1) & _MASK
        k = ((k << 15) | (k >> 17)) & _MASK
        k = (k * _C2) & _MASK
        hx = h ^ k
        hx = ((hx << 13) | (hx >> 19)) & _MASK
        hx = (hx * 5 + 0xE6546B64) & _MASK
        h = np.where(active, hx, h)

    # tails (1–3 trailing bytes), gathered per row
    tail_len = lengths & 3
    base = (lengths & ~3).astype(np.int64)
    rows = np.arange(n)
    b0 = buf[rows, base].astype(np.uint64)
    b1 = buf[rows, base + 1].astype(np.uint64)
    b2 = buf[rows, base + 2].astype(np.uint64)
    k = np.where(tail_len >= 3, b2 << 16, 0).astype(np.uint64)
    k = np.where(tail_len >= 2, k ^ (b1 << 8), k)
    k = np.where(tail_len >= 1, k ^ b0, k)
    k = (k * _C1) & _MASK
    k = ((k << 15) | (k >> 17)) & _MASK
    k = (k * _C2) & _MASK
    h = np.where(tail_len >= 1, h ^ k, h)

    # finalization (fmix32)
    h ^= lengths.astype(np.uint64)
    h ^= h >> 16
    h = (h * _FC1) & _MASK
    h ^= h >> 13
    h = (h * _FC2) & _MASK
    h ^= h >> 16
    return [int(x) for x in h]


def _murmur3_32_fixed(words, n: int, n_words: int, seed: int):
    """Murmur3_32 over ``n`` equal-length rows of ``n_words`` u32 words each.

    No tails, no per-row activity masks — the fixed-width fast path the
    classifier packing enables. ``words`` is ``[n, n_words]`` uint64 holding
    u32 word values.
    """
    import numpy as np

    h = np.full(n, seed & _MASK, dtype=np.uint64)
    for j in range(n_words):
        k = (words[:, j] * _C1) & _MASK
        k = ((k << 15) | (k >> 17)) & _MASK
        k = (k * _C2) & _MASK
        h ^= k
        h = ((h << 13) | (h >> 19)) & _MASK
        h = (h * 5 + 0xE6546B64) & _MASK
    h ^= np.uint64(n_words * 4)
    h ^= h >> 16
    h = (h * _FC1) & _MASK
    h ^= h >> 13
    h = (h * _FC2) & _MASK
    h ^= h >> 16
    return [int(x) for x in h]


def token_for_batch(parts_list, seed: int = 0x5D5):
    """Batched :func:`token_for`: one vectorized murmur pass over all rows.

    ``parts_list`` is a sequence of classifier tuples; returns ``List[int]``
    tokens, elementwise equal to ``[token_for(p, seed) for p in parts_list]``.
    Uniform-arity batches (the route-resolution case: one call per mask level)
    take the fixed-width path — codes go straight into an ``[N, arity]``
    uint64 matrix, no per-row byte strings at all.
    """
    import numpy as np

    n = len(parts_list)
    if n == 0:
        return []
    arity = len(parts_list[0])
    if any(len(p) != arity for p in parts_list):
        # mixed arity (generic API use): per-row packing, variable-width path
        return murmur3_32_batch([_pack(p) for p in parts_list], seed)
    if arity == 0:
        return [murmur3_32(b"", seed)] * n
    codes = np.fromiter(
        (_part_code(x) for parts in parts_list for x in parts),
        dtype=np.uint64,
        count=n * arity,
    ).reshape(n, arity)
    # each 8-byte code is two little-endian u32 words: low word first, in
    # exactly the byte order _pack() emits
    words = (codes & 0xFFFFFFFF), (codes >> np.uint64(32))
    interleaved = np.empty((n, arity * 2), dtype=np.uint64)
    interleaved[:, 0::2] = words[0]
    interleaved[:, 1::2] = words[1]
    return _murmur3_32_fixed(interleaved, n, arity * 2, seed)
