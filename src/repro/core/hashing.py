"""MurmurHash3 (x86 32-bit) — the paper's channel-token hashing scheme (§4.3).

PAIO concatenates a context's classifiers and hashes them into a fixed-size
token with MurmurHash3 to build the request→channel / request→enforcement-object
maps. We implement murmur3_32 exactly (validated against the reference vectors
of Appleby's SMHasher in tests) so differentiation tokens are stable across
processes — a requirement for rules sent by an *external* control plane to refer
to the same tokens the data plane computes.
"""
from __future__ import annotations

_C1 = 0xCC9E2D51
_C2 = 0x1B873593
_MASK = 0xFFFFFFFF


def _rotl32(x: int, r: int) -> int:
    return ((x << r) | (x >> (32 - r))) & _MASK


def murmur3_32(data: bytes, seed: int = 0) -> int:
    """Pure-python MurmurHash3 x86_32."""
    length = len(data)
    h = seed & _MASK
    rounded = length & ~0x3
    for i in range(0, rounded, 4):
        k = int.from_bytes(data[i : i + 4], "little")
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
        h = _rotl32(h, 13)
        h = (h * 5 + 0xE6546B64) & _MASK
    # tail
    k = 0
    tail = data[rounded:]
    if len(tail) >= 3:
        k ^= tail[2] << 16
    if len(tail) >= 2:
        k ^= tail[1] << 8
    if len(tail) >= 1:
        k ^= tail[0]
        k = (k * _C1) & _MASK
        k = _rotl32(k, 15)
        k = (k * _C2) & _MASK
        h ^= k
    # finalization
    h ^= length
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _MASK
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _MASK
    h ^= h >> 16
    return h


def token_for(parts: tuple, seed: int = 0x5D5) -> int:
    """Differentiation token: concatenate classifiers, murmur-hash to 32 bits.

    ``parts`` is any tuple of ints/strings (a subset of Context classifiers as
    chosen by the stage's differentiation spec).
    """
    raw = "\x1f".join(str(p) for p in parts).encode("utf-8")
    return murmur3_32(raw, seed)
