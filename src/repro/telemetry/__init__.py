from .metrics import MetricRegistry, ProcIOReader, StepTimer

__all__ = ["MetricRegistry", "ProcIOReader", "StepTimer"]
