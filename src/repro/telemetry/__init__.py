from .histogram import (
    NBUCKETS,
    WAIT_BOUNDS_MS,
    Histogram,
    bucket_index,
    merge_counts,
    quantile_from_counts,
)
from .metrics import (
    MetricRegistry,
    MetricSample,
    ProcIOReader,
    StepTimer,
    get_registry,
    quantile,
    set_registry,
)

#: exporter names resolve lazily (module __getattr__): the data plane imports
#: repro.telemetry for the registry; it must not pay for http.server unless
#: something actually starts/renders an exporter
_EXPORTER_NAMES = frozenset(
    {"MetricsExporter", "parse_labels", "parse_prometheus", "render_prometheus", "start_exporter"}
)

__all__ = [
    "Histogram",
    "MetricRegistry",
    "MetricSample",
    "MetricsExporter",
    "NBUCKETS",
    "ProcIOReader",
    "StepTimer",
    "WAIT_BOUNDS_MS",
    "bucket_index",
    "get_registry",
    "merge_counts",
    "parse_labels",
    "parse_prometheus",
    "quantile",
    "quantile_from_counts",
    "render_prometheus",
    "set_registry",
    "start_exporter",
]


def __getattr__(name: str):
    if name in _EXPORTER_NAMES:
        from . import exporter

        return getattr(exporter, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
