from .metrics import ProcIOReader, StepTimer

__all__ = ["ProcIOReader", "StepTimer"]
