from .metrics import (
    MetricRegistry,
    MetricSample,
    ProcIOReader,
    StepTimer,
    get_registry,
    quantile,
    set_registry,
)

#: exporter names resolve lazily (module __getattr__): the data plane imports
#: repro.telemetry for the registry; it must not pay for http.server unless
#: something actually starts/renders an exporter
_EXPORTER_NAMES = frozenset(
    {"MetricsExporter", "parse_prometheus", "render_prometheus", "start_exporter"}
)

__all__ = [
    "MetricRegistry",
    "MetricSample",
    "MetricsExporter",
    "ProcIOReader",
    "StepTimer",
    "get_registry",
    "parse_prometheus",
    "quantile",
    "render_prometheus",
    "set_registry",
    "start_exporter",
]


def __getattr__(name: str):
    if name in _EXPORTER_NAMES:
        from . import exporter

        return getattr(exporter, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
