"""Fixed-bucket mergeable latency histograms (the fleet metric plane's core).

PR 3's wait-percentile telemetry kept a sliding sample window per channel —
cheap locally, but windows from two stages (or two collect ticks) cannot be
combined without the raw samples, so a ``scope: global`` flow had no honest
fleet p99, and batch enforcement degraded to one mean observation per batch.
Fixed-bucket histograms fix both structurally:

* **exact, associative merge** — bucket counts add elementwise, so
  merge(shard histograms) == one histogram over the union of observations,
  bucket for bucket (the property the fleet views and the cross-tick window
  accumulation rely on);
* **per-op weights** — a batch contributes one bucket increment per request
  (or a weighted increment), never a collapsed mean;
* **native Prometheus exposition** — the bucket layout IS the
  ``_bucket{le=...}`` family; no summary emulation.

Bucket bounds are a fixed 1-2-5 ladder per decade from 1 µs to 100 s (in
milliseconds), shared process-wide so every histogram in the system is
mergeable with every other. Quantiles interpolate linearly inside the
containing bucket — resolution is the bucket width (≤ 2.5x), counts are
exact.
"""
from __future__ import annotations

from bisect import bisect_left
from typing import Iterable, List, Optional, Sequence, Tuple


def _build_wait_bounds() -> Tuple[float, ...]:
    bounds: List[float] = []
    for exp in range(-3, 5):  # 0.001 ms .. 50 000 ms
        scale = 10.0 ** exp
        bounds.extend((1.0 * scale, 2.0 * scale, 5.0 * scale))
    bounds.append(1e5)  # 100 s — anything above lands in +Inf
    return tuple(bounds)


#: upper bucket bounds (inclusive, ms) for wait/latency histograms — one
#: shared layout so every snapshot/stage/fleet histogram merges exactly
WAIT_BOUNDS_MS: Tuple[float, ...] = _build_wait_bounds()
#: bucket count including the implicit +Inf bucket
NBUCKETS: int = len(WAIT_BOUNDS_MS) + 1


def bucket_index(value_ms: float, bounds: Sequence[float] = WAIT_BOUNDS_MS) -> int:
    """Index of the bucket ``value_ms`` falls in (``le`` semantics: a value
    exactly on a bound counts in that bound's bucket)."""
    return bisect_left(bounds, value_ms)


def merge_counts(a: Sequence[int], b: Sequence[int]) -> Tuple[int, ...]:
    """Elementwise sum of two bucket-count vectors. Either may be empty
    (an old-wire snapshot with no histogram) — empty merges as all-zero."""
    if not a:
        return tuple(b)
    if not b:
        return tuple(a)
    if len(a) != len(b):
        raise ValueError(f"bucket layout mismatch: {len(a)} vs {len(b)} buckets")
    return tuple(x + y for x, y in zip(a, b))


def quantile_from_counts(
    counts: Sequence[int], q: float, bounds: Sequence[float] = WAIT_BOUNDS_MS
) -> float:
    """Nearest-rank quantile over bucket counts, linearly interpolated inside
    the containing bucket (0 when empty; the +Inf bucket reports the last
    finite bound — the histogram cannot say more)."""
    total = sum(counts)
    if total <= 0:
        return 0.0
    k = min(int(q * total), total - 1)  # nearest-rank, matches telemetry.quantile
    cum = 0
    for i, c in enumerate(counts):
        if c <= 0:
            continue
        if cum + c > k:
            if i >= len(bounds):  # +Inf bucket: no finite upper edge
                return float(bounds[-1])
            lo = float(bounds[i - 1]) if i > 0 else 0.0
            hi = float(bounds[i])
            return lo + (hi - lo) * ((k - cum + 1) / c)
        cum += c
    return float(bounds[-1])  # pragma: no cover — unreachable (total > 0)


class Histogram:
    """A mergeable fixed-bucket histogram: exact integer counts per bucket
    plus the running sum of observations. Not thread-safe — owners
    (ChannelStats, MetricRegistry) lock around it."""

    __slots__ = ("bounds", "counts", "sum")

    def __init__(
        self,
        bounds: Sequence[float] = WAIT_BOUNDS_MS,
        counts: Optional[Sequence[int]] = None,
        sum_: float = 0.0,
    ) -> None:
        self.bounds = tuple(bounds)
        n = len(self.bounds) + 1
        if counts is None:
            self.counts: List[int] = [0] * n
        else:
            if len(counts) != n:
                raise ValueError(f"expected {n} bucket counts, got {len(counts)}")
            self.counts = list(counts)
        self.sum = float(sum_)

    @property
    def count(self) -> int:
        return sum(self.counts)

    def observe(self, value_ms: float) -> None:
        self.counts[bisect_left(self.bounds, value_ms)] += 1
        self.sum += value_ms

    def add(self, value_ms: float, n: int) -> None:
        """Weighted observation: ``n`` ops at ``value_ms`` each (one bucket
        increment for a whole batch whose per-op values are not known)."""
        if n <= 0:
            return
        self.counts[bisect_left(self.bounds, value_ms)] += n
        self.sum += value_ms * n

    def observe_many(self, values_ms: Iterable[float]) -> None:
        counts, bounds = self.counts, self.bounds
        total = 0.0
        for v in values_ms:
            counts[bisect_left(bounds, v)] += 1
            total += v
        self.sum += total

    def merge(self, other: "Histogram") -> "Histogram":
        if self.bounds != other.bounds:
            raise ValueError("cannot merge histograms with different bucket layouts")
        self.counts = [x + y for x, y in zip(self.counts, other.counts)]
        self.sum += other.sum
        return self

    def add_counts(self, counts: Sequence[int], sum_: float = 0.0) -> None:
        """Merge a raw count vector (e.g. a snapshot's windowed delta) in."""
        if not counts:
            return
        if len(counts) != len(self.counts):
            raise ValueError(
                f"bucket layout mismatch: {len(counts)} vs {len(self.counts)} buckets"
            )
        mine = self.counts
        for i, c in enumerate(counts):
            if c:
                mine[i] += c
        self.sum += float(sum_)

    def quantile(self, q: float) -> float:
        return quantile_from_counts(self.counts, q, self.bounds)

    def cumulative(self) -> List[Tuple[float, int]]:
        """``(le_bound, cumulative_count)`` rows for the finite bounds, the
        exact shape Prometheus ``_bucket`` rendering wants (the ``+Inf`` row
        is the total count)."""
        out: List[Tuple[float, int]] = []
        cum = 0
        for bound, c in zip(self.bounds, self.counts):
            cum += c
            out.append((bound, cum))
        return out

    def snapshot_counts(self) -> Tuple[int, ...]:
        return tuple(self.counts)

    def reset(self) -> None:
        for i in range(len(self.counts)):
            self.counts[i] = 0
        self.sum = 0.0

    def __eq__(self, other: object) -> bool:
        return (
            isinstance(other, Histogram)
            and self.bounds == other.bounds
            and self.counts == other.counts
        )

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return f"Histogram(count={self.count}, sum={self.sum:.3f})"
