"""Telemetry: /proc I/O counters (paper §4.3's control-plane side channel),
step-time tracking for the straggler monitor, and the pluggable metric
registry the policy trigger engine samples (Crystal-style: metrics are
injected at runtime, controllers subscribe by name)."""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional


class ProcIOReader:
    """Reads read_bytes/write_bytes from /proc/<pid>/io (paper §4.3: the
    control plane compares block-layer counters with stage statistics)."""

    def __init__(self, pid: Optional[int] = None) -> None:
        import os

        self.path = f"/proc/{pid or os.getpid()}/io"
        self._last: Dict[str, int] = {}

    def read(self) -> Dict[str, int]:
        counters: Dict[str, int] = {}
        try:
            with open(self.path) as f:
                for line in f:
                    key, _, val = line.partition(":")
                    counters[key.strip()] = int(val)
        except OSError:
            pass
        return counters

    def delta(self) -> Dict[str, int]:
        now = self.read()
        d = {k: now.get(k, 0) - self._last.get(k, 0) for k in now}
        self._last = now
        return d


class MetricRegistry:
    """Named metric sources the control plane samples every collect tick.

    A *source* is a zero-arg callable returning the metric's current value
    (a gauge). Stage statistics are pushed into the registry by the policy
    runtime under ``<stage>.<channel>.<field>`` names; anything else (step
    timers, /proc counters, model-serving queue depths) registers a callable
    and becomes addressable from policy trigger predicates by name.
    """

    def __init__(self) -> None:
        self._sources: Dict[str, Callable[[], float]] = {}
        self._gauges: Dict[str, float] = {}
        self._lock = threading.Lock()

    def register(self, name: str, source: Callable[[], float]) -> None:
        with self._lock:
            self._sources[name] = source

    def unregister(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)
            self._gauges.pop(name, None)

    def set_gauge(self, name: str, value: float) -> None:
        """Push-style update (used for per-collect stage statistics)."""
        with self._lock:
            self._gauges[name] = float(value)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(set(self._sources) | set(self._gauges))

    def sample(self) -> Dict[str, float]:
        """One coherent sample of every metric (pull sources + pushed gauges).

        A source that raises is skipped for this tick (a dead metric must not
        take down the control loop) — its last pushed value, if any, remains.
        """
        with self._lock:
            sources = list(self._sources.items())
            out = dict(self._gauges)
        for name, fn in sources:
            try:
                out[name] = float(fn())
            except Exception:  # noqa: BLE001 — sampling is best-effort
                continue
        return out

    def register_step_timer(self, name: str, timer: "StepTimer") -> None:
        """Bridge a StepTimer: exposes ``<name>.mean_ms`` and ``<name>.p99_ms``."""
        self.register(f"{name}.mean_ms", lambda: timer.mean() * 1e3)
        self.register(f"{name}.p99_ms", lambda: timer.percentile(99) * 1e3)

    def register_proc_io(self, name: str = "proc_io", pid: Optional[int] = None) -> None:
        """Bridge /proc I/O counters: ``<name>.read_bytes`` / ``<name>.write_bytes``
        report the delta since the previous sample (a per-tick rate source).
        Each metric gets its own reader so the two delta streams stay
        independent no matter how often either is sampled."""

        def _mk(key: str) -> Callable[[], float]:
            reader = ProcIOReader(pid)
            return lambda: float(reader.delta().get(key, 0))

        self.register(f"{name}.read_bytes", _mk("read_bytes"))
        self.register(f"{name}.write_bytes", _mk("write_bytes"))


class StepTimer:
    """Sliding-window step-duration stats; feeds the straggler monitor."""

    def __init__(self, window: int = 50) -> None:
        self._durations: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        with self._lock:
            self._durations.append(dt)
        return dt

    def record(self, seconds: float) -> None:
        with self._lock:
            self._durations.append(seconds)

    def mean(self) -> float:
        with self._lock:
            return sum(self._durations) / len(self._durations) if self._durations else 0.0

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self._durations:
                return 0.0
            data = sorted(self._durations)
            k = min(int(q / 100.0 * len(data)), len(data) - 1)
            return data[k]
