"""Telemetry: /proc I/O counters (paper §4.3's control-plane side channel),
step-time tracking for the straggler monitor, and the shared metric registry.

The registry started as a policy-engine internal (the trigger engine samples
it by dotted name); it is now the process-wide observability surface: stage /
channel / serve statistics publish into it as **gauges**, **counters**,
**windowed summaries** (p50/p95/p99 over a bounded sample window) and
**mergeable histograms** (:mod:`repro.telemetry.histogram` — cumulative
fixed-bucket counts, the fleet metric plane's exchange format), and the
:mod:`repro.telemetry.exporter` renders one coherent ``collect()`` of it in
Prometheus text exposition for scraping from outside the process.

Two naming layers coexist deliberately:

* the *registry name* is a dotted string (``serve.tenant_a.wait_ms``) —
  stable, addressable from policy trigger predicates;
* the *export identity* is an optional descriptor (family + labels, e.g.
  ``paio_channel_wait_ms{stage="serve",channel="tenant_a"}``) attached via
  :meth:`MetricRegistry.describe`; undescribed metrics export under their
  sanitized dotted name prefixed ``paio_``.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Mapping, Optional, Sequence, Tuple

from .histogram import Histogram, quantile_from_counts

#: quantiles summaries report, as (label, fraction)
SUMMARY_QUANTILES: Tuple[Tuple[str, float], ...] = (("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99))
#: registry-name suffix ↔ quantile label for summary sampling
_SUMMARY_KEYS = (("p50", 0.5), ("p95", 0.95), ("p99", 0.99))


def quantile(sorted_values: List[float], q: float) -> float:
    """Nearest-rank quantile over an already-sorted list (0 when empty)."""
    if not sorted_values:
        return 0.0
    k = min(int(q * len(sorted_values)), len(sorted_values) - 1)
    return sorted_values[k]


class ProcIOReader:
    """Reads read_bytes/write_bytes from /proc/<pid>/io (paper §4.3: the
    control plane compares block-layer counters with stage statistics)."""

    def __init__(self, pid: Optional[int] = None) -> None:
        import os

        self.path = f"/proc/{pid or os.getpid()}/io"
        self._last: Dict[str, int] = {}

    def read(self) -> Dict[str, int]:
        counters: Dict[str, int] = {}
        try:
            with open(self.path) as f:
                for line in f:
                    key, _, val = line.partition(":")
                    counters[key.strip()] = int(val)
        except OSError:
            pass
        return counters

    def delta(self) -> Dict[str, int]:
        now = self.read()
        d = {k: now.get(k, 0) - self._last.get(k, 0) for k in now}
        self._last = now
        return d


@dataclass
class MetricSample:
    """One metric in a registry ``collect()``: enough to render any
    exposition format without reaching back into the registry."""

    name: str  #: dotted registry name
    kind: str  #: "gauge" | "counter" | "summary" | "histogram"
    value: float = 0.0  #: gauge/counter value; summaries use the fields below
    family: Optional[str] = None  #: export family name (None → derived)
    labels: Dict[str, str] = field(default_factory=dict)
    quantiles: Dict[str, float] = field(default_factory=dict)  #: summaries only
    count: int = 0  #: summaries/histograms: total observations ever
    sum: float = 0.0  #: summaries/histograms: total of all observations ever
    #: histograms only: ``(le_bound, cumulative_count)`` rows for the finite
    #: bounds (the ``+Inf`` row is ``count``)
    buckets: List[Tuple[float, int]] = field(default_factory=list)


class _Summary:
    """Bounded sliding window of observations + cumulative count/sum.

    Percentiles are computed over the retained window (the last ``window``
    observations); ``count``/``sum`` are cumulative since creation, matching
    Prometheus summary semantics.
    """

    __slots__ = ("buf", "count", "sum")

    def __init__(self, window: int) -> None:
        self.buf: Deque[float] = deque(maxlen=window)
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        self.buf.append(value)
        self.count += 1
        self.sum += value


class MetricRegistry:
    """Named metrics the control plane samples and the exporter renders.

    Five metric shapes:

    * **source** — a zero-arg callable returning the current value (pull);
    * **gauge** — a pushed point-in-time value (``set_gauge``);
    * **counter** — a pushed monotonically-increasing total (``inc``);
    * **summary** — pushed observations with windowed p50/p95/p99
      (``observe``);
    * **histogram** — cumulative fixed-bucket counts merged in per collect
      tick (``hist_add``), rendered as native Prometheus
      ``_bucket``/``_sum``/``_count`` families and mergeable across
      processes (the fleet metric plane).

    ``sample()`` flattens everything into ``{dotted name: float}`` for the
    trigger engine (summaries and histograms contribute ``<name>.p50/.p95/
    .p99/.mean/.count``); ``collect()`` returns structured
    :class:`MetricSample` rows for the exporter.
    """

    def __init__(self, summary_window: int = 1024) -> None:
        self._sources: Dict[str, Callable[[], float]] = {}
        self._gauges: Dict[str, float] = {}
        self._counters: Dict[str, float] = {}
        self._summaries: Dict[str, _Summary] = {}
        self._hists: Dict[str, Histogram] = {}
        #: export metadata: name → (family, labels)
        self._descriptors: Dict[str, Tuple[str, Dict[str, str]]] = {}
        self._summary_window = int(summary_window)
        self._lock = threading.Lock()

    # -- registration ------------------------------------------------------
    def register(self, name: str, source: Callable[[], float]) -> None:
        with self._lock:
            self._sources[name] = source

    def unregister(self, name: str) -> None:
        with self._lock:
            self._sources.pop(name, None)
            self._gauges.pop(name, None)
            self._counters.pop(name, None)
            self._summaries.pop(name, None)
            self._hists.pop(name, None)
            self._descriptors.pop(name, None)

    def describe(self, name: str, family: str, labels: Optional[Mapping[str, str]] = None) -> None:
        """Attach export identity to ``name``: the Prometheus family and label
        set it renders under. Idempotent; cheap enough to call per publish."""
        with self._lock:
            self._descriptors[name] = (family, dict(labels or {}))

    # -- pushes ------------------------------------------------------------
    def set_gauge(self, name: str, value: float) -> None:
        """Push-style update (used for per-collect stage statistics)."""
        with self._lock:
            self._gauges[name] = float(value)

    def update_gauges(self, values: Mapping[str, float]) -> None:
        """Bulk ``set_gauge``: one lock acquisition for a whole stats tick
        (the control loop publishes O(stages×channels) gauges per tick)."""
        with self._lock:
            self._gauges.update(values)

    def inc(self, name: str, delta: float = 1.0) -> None:
        """Increment counter ``name`` (created at 0 on first use)."""
        with self._lock:
            self._counters[name] = self._counters.get(name, 0.0) + delta

    def observe(self, name: str, value: float) -> None:
        """Add one observation to summary ``name`` (created on first use)."""
        with self._lock:
            s = self._summaries.get(name)
            if s is None:
                s = self._summaries[name] = _Summary(self._summary_window)
            s.observe(float(value))

    def hist_add(self, name: str, counts: Sequence[int], sum_delta: float = 0.0) -> None:
        """Merge a windowed bucket-count delta into cumulative histogram
        ``name`` (created on first use — an all-zero delta pre-registers the
        family at zero). Counts follow the shared WAIT_BOUNDS_MS layout;
        ``sum_delta`` is the window's total in the same unit (ms)."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            h.add_counts(counts, sum_delta)

    # -- reads -------------------------------------------------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(
                set(self._sources)
                | set(self._gauges)
                | set(self._counters)
                | set(self._summaries)
                | set(self._hists)
            )

    def gauge_count(self, prefix: str = "", suffix: str = "") -> int:
        """Count pushed gauges matching ``prefix``/``suffix`` — O(n) with no
        sort/alloc, cheap enough for derived sources sampled every tick."""
        with self._lock:
            return sum(
                1 for n in self._gauges if n.startswith(prefix) and n.endswith(suffix)
            )

    def sample(self) -> Dict[str, float]:
        """One coherent flat sample of every metric (for trigger predicates).

        A source that raises is skipped for this tick (a dead metric must not
        take down the control loop) — its last pushed value, if any, remains.
        """
        with self._lock:
            sources = list(self._sources.items())
            out = dict(self._gauges)
            out.update(self._counters)
            # copy windows under the lock, sort OUTSIDE it: the serve decode
            # hot path observes into these summaries and must not block
            # behind O(n log n) sorts per tick/scrape
            summaries = [(n, list(s.buf), s.count, s.sum) for n, s in self._summaries.items()]
            hists = [(n, tuple(h.counts), h.sum) for n, h in self._hists.items()]
        for name, values, count, total in summaries:
            values.sort()
            for suffix, q in _SUMMARY_KEYS:
                out[f"{name}.{suffix}"] = quantile(values, q)
            out[f"{name}.mean"] = (total / count) if count else 0.0
            out[f"{name}.count"] = float(count)
        for name, counts, total in hists:
            n_obs = sum(counts)
            for suffix, q in _SUMMARY_KEYS:
                out[f"{name}.{suffix}"] = quantile_from_counts(counts, q)
            out[f"{name}.mean"] = (total / n_obs) if n_obs else 0.0
            out[f"{name}.count"] = float(n_obs)
        for name, fn in sources:
            try:
                out[name] = float(fn())
            except Exception:  # noqa: BLE001 — sampling is best-effort
                continue
        return out

    def collect(self) -> List[MetricSample]:
        """Structured snapshot for exposition (exporter endpoint)."""
        with self._lock:
            desc = dict(self._descriptors)
            gauges = list(self._gauges.items())
            counters = list(self._counters.items())
            summaries = [(n, list(s.buf), s.count, s.sum) for n, s in self._summaries.items()]
            # O(buckets) per histogram — cheap enough to flatten under the lock
            hists = [(n, h.cumulative(), h.count, h.sum) for n, h in self._hists.items()]
            sources = list(self._sources.items())
        for _, values, _, _ in summaries:
            values.sort()  # outside the lock — see sample()
        out: List[MetricSample] = []

        def meta(name: str) -> Tuple[Optional[str], Dict[str, str]]:
            fam, labels = desc.get(name, (None, {}))
            return fam, dict(labels)

        for name, value in gauges:
            fam, labels = meta(name)
            out.append(MetricSample(name, "gauge", value, fam, labels))
        for name, value in counters:
            fam, labels = meta(name)
            out.append(MetricSample(name, "counter", value, fam, labels))
        for name, values, count, total in summaries:
            fam, labels = meta(name)
            out.append(
                MetricSample(
                    name,
                    "summary",
                    family=fam,
                    labels=labels,
                    quantiles={ql: quantile(values, q) for ql, q in SUMMARY_QUANTILES},
                    count=count,
                    sum=total,
                )
            )
        for name, bucket_rows, count, total in hists:
            fam, labels = meta(name)
            out.append(
                MetricSample(
                    name,
                    "histogram",
                    family=fam,
                    labels=labels,
                    count=count,
                    sum=total,
                    buckets=bucket_rows,
                )
            )
        for name, fn in sources:
            try:
                value = float(fn())
            except Exception:  # noqa: BLE001 — a dead source is skipped
                continue
            fam, labels = meta(name)
            out.append(MetricSample(name, "gauge", value, fam, labels))
        return out

    # -- bridges -----------------------------------------------------------
    def register_step_timer(self, name: str, timer: "StepTimer") -> None:
        """Bridge a StepTimer: exposes ``<name>.mean_ms`` and ``<name>.p99_ms``."""
        self.register(f"{name}.mean_ms", lambda: timer.mean() * 1e3)
        self.register(f"{name}.p99_ms", lambda: timer.percentile(99) * 1e3)

    def register_proc_io(self, name: str = "proc_io", pid: Optional[int] = None) -> None:
        """Bridge /proc I/O counters: ``<name>.read_bytes`` / ``<name>.write_bytes``
        report the delta since the previous sample (a per-tick rate source).
        Each metric gets its own reader so the two delta streams stay
        independent no matter how often either is sampled."""

        def _mk(key: str) -> Callable[[], float]:
            reader = ProcIOReader(pid)
            return lambda: float(reader.delta().get(key, 0))

        self.register(f"{name}.read_bytes", _mk("read_bytes"))
        self.register(f"{name}.write_bytes", _mk("write_bytes"))


# --------------------------------------------------------------------------- #
# process-wide registry                                                        #
# --------------------------------------------------------------------------- #
_global_lock = threading.Lock()
_global_registry: Optional[MetricRegistry] = None


def get_registry() -> MetricRegistry:
    """The process-wide shared registry: the default publication target for
    control planes and serve engines, and the default source for the
    exporter — everything that publishes here is visible on one endpoint."""
    global _global_registry
    with _global_lock:
        if _global_registry is None:
            _global_registry = MetricRegistry()
        return _global_registry


def set_registry(registry: MetricRegistry) -> MetricRegistry:
    """Swap the process-wide registry (tests use this for isolation);
    returns the previous one (possibly None on first call)."""
    global _global_registry
    with _global_lock:
        prev, _global_registry = _global_registry, registry
    return prev  # type: ignore[return-value]


class StepTimer:
    """Sliding-window step-duration stats; feeds the straggler monitor."""

    def __init__(self, window: int = 50) -> None:
        self._durations: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        with self._lock:
            self._durations.append(dt)
        return dt

    def record(self, seconds: float) -> None:
        with self._lock:
            self._durations.append(seconds)

    def mean(self) -> float:
        with self._lock:
            return sum(self._durations) / len(self._durations) if self._durations else 0.0

    def percentile(self, q: float) -> float:
        with self._lock:
            data = sorted(self._durations)
        return quantile(data, q / 100.0)
