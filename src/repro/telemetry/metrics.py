"""Telemetry: /proc I/O counters (paper §4.3's control-plane side channel)
and step-time tracking for the straggler monitor."""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Deque, Dict, Optional


class ProcIOReader:
    """Reads read_bytes/write_bytes from /proc/<pid>/io (paper §4.3: the
    control plane compares block-layer counters with stage statistics)."""

    def __init__(self, pid: Optional[int] = None) -> None:
        import os

        self.path = f"/proc/{pid or os.getpid()}/io"
        self._last: Dict[str, int] = {}

    def read(self) -> Dict[str, int]:
        counters: Dict[str, int] = {}
        try:
            with open(self.path) as f:
                for line in f:
                    key, _, val = line.partition(":")
                    counters[key.strip()] = int(val)
        except OSError:
            pass
        return counters

    def delta(self) -> Dict[str, int]:
        now = self.read()
        d = {k: now.get(k, 0) - self._last.get(k, 0) for k in now}
        self._last = now
        return d


class StepTimer:
    """Sliding-window step-duration stats; feeds the straggler monitor."""

    def __init__(self, window: int = 50) -> None:
        self._durations: Deque[float] = deque(maxlen=window)
        self._lock = threading.Lock()
        self._t0: Optional[float] = None

    def start(self) -> None:
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - (self._t0 or time.monotonic())
        with self._lock:
            self._durations.append(dt)
        return dt

    def record(self, seconds: float) -> None:
        with self._lock:
            self._durations.append(seconds)

    def mean(self) -> float:
        with self._lock:
            return sum(self._durations) / len(self._durations) if self._durations else 0.0

    def percentile(self, q: float) -> float:
        with self._lock:
            if not self._durations:
                return 0.0
            data = sorted(self._durations)
            k = min(int(q / 100.0 * len(data)), len(data) - 1)
            return data[k]
