"""Prometheus-style text exposition for the shared metric registry.

Two consumption paths, same rendering:

* :func:`render_prometheus` — pure function registry → exposition text, the
  ``collect()`` API for benchmarks/tests that want the metrics in-process;
* :class:`MetricsExporter` — a plain-HTTP daemon thread serving the text on
  ``/metrics`` (and ``/``), so policies, stage statistics and benchmarks are
  observable from *outside* the process with nothing but ``curl``.

Naming scheme (full table in docs/operations.md § Metric naming):

* described metrics render under their export family + labels, e.g.
  ``paio_channel_wait_p99_ms{stage="serve",channel="tenant_a"}``;
* undescribed dotted registry names are sanitized verbatim:
  ``train.step.p99_ms`` → ``paio_train_step_p99_ms``;
* counters get the conventional ``_total`` suffix, summaries render
  ``{quantile="0.5|0.95|0.99"}`` rows plus ``_count`` / ``_sum``, histograms
  render native cumulative ``_bucket{le=...}`` rows (ending in ``+Inf``)
  plus ``_sum`` / ``_count``.

Label values are escaped per the text format (backslash, double-quote,
newline) and :func:`parse_labels` reverses the escaping, so a pathological
flow name (``evil"} 9``) round-trips instead of corrupting the scrape.
"""
from __future__ import annotations

import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, List, Optional, Sequence

from .metrics import MetricRegistry, MetricSample, get_registry

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_SANITIZE = re.compile(r"[^a-zA-Z0-9_:]")

#: hosts considered loopback-only for the exporter's bind-address guard
LOOPBACK_HOSTS = frozenset({"127.0.0.1", "localhost", "::1"})


def family_name(sample: MetricSample) -> str:
    """Prometheus family for a sample: its descriptor family, or the
    sanitized dotted name prefixed ``paio_``."""
    fam = sample.family
    if fam is None:
        fam = "paio_" + _NAME_SANITIZE.sub("_", sample.name)
    if fam[0].isdigit():
        fam = "_" + fam
    return fam


def _escape_label(value: str) -> str:
    return value.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_text(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_escape_label(str(v))}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt(value: float) -> str:
    # integral floats render without the trailing .0 (Prometheus-idiomatic)
    return str(int(value)) if float(value).is_integer() else repr(float(value))


def render_prometheus(
    registry: Optional[MetricRegistry] = None,
    allow_prefixes: Optional[Sequence[str]] = None,
) -> str:
    """Render one coherent scrape of ``registry`` (default: the process-wide
    one) in Prometheus text exposition format v0.0.4.

    ``allow_prefixes`` (when given) is an allowlist: only samples whose
    exported family name *or* raw dotted registry name starts with one of the
    prefixes are rendered — e.g. ``("paio_stage_", "paio_policy_")`` serves
    fleet liveness and policy versions while keeping per-tenant channel
    gauges off the endpoint."""
    registry = registry if registry is not None else get_registry()
    samples = registry.collect()
    if allow_prefixes is not None:
        prefixes = tuple(allow_prefixes)
        samples = [
            s
            for s in samples
            if any(family_name(s).startswith(p) or s.name.startswith(p) for p in prefixes)
        ]
    # group by family so each gets exactly one # TYPE header
    by_family: Dict[str, List[MetricSample]] = {}
    for s in samples:
        by_family.setdefault(family_name(s), []).append(s)
    lines: List[str] = []
    for fam in sorted(by_family):
        group = by_family[fam]
        kind = group[0].kind
        if kind == "counter":
            lines.append(f"# TYPE {fam}_total counter")
            for s in group:
                lines.append(f"{fam}_total{_labels_text(s.labels)} {_fmt(s.value)}")
        elif kind == "summary":
            lines.append(f"# TYPE {fam} summary")
            for s in group:
                for ql, qv in s.quantiles.items():
                    qlabel = 'quantile="%s"' % ql
                    lines.append(f"{fam}{_labels_text(s.labels, qlabel)} {_fmt(qv)}")
                lines.append(f"{fam}_count{_labels_text(s.labels)} {s.count}")
                lines.append(f"{fam}_sum{_labels_text(s.labels)} {_fmt(s.sum)}")
        elif kind == "histogram":
            lines.append(f"# TYPE {fam} histogram")
            for s in group:
                for bound, cum in s.buckets:
                    lelabel = 'le="%s"' % _fmt(bound)
                    lines.append(f"{fam}_bucket{_labels_text(s.labels, lelabel)} {cum}")
                inf_label = 'le="+Inf"'
                lines.append(f"{fam}_bucket{_labels_text(s.labels, inf_label)} {s.count}")
                lines.append(f"{fam}_sum{_labels_text(s.labels)} {_fmt(s.sum)}")
                lines.append(f"{fam}_count{_labels_text(s.labels)} {s.count}")
        else:
            lines.append(f"# TYPE {fam} gauge")
            for s in group:
                lines.append(f"{fam}{_labels_text(s.labels)} {_fmt(s.value)}")
    return "\n".join(lines) + "\n"


def _unescape_label(value: str) -> str:
    """Inverse of :func:`_escape_label` (``\\\\`` → backslash, ``\\"`` →
    quote, ``\\n`` → newline; unknown escapes pass through verbatim)."""
    if "\\" not in value:
        return value
    out: List[str] = []
    i, n = 0, len(value)
    while i < n:
        c = value[i]
        if c == "\\" and i + 1 < n:
            nxt = value[i + 1]
            if nxt == "\\" or nxt == '"':
                out.append(nxt)
                i += 2
                continue
            if nxt == "n":
                out.append("\n")
                i += 2
                continue
        out.append(c)
        i += 1
    return "".join(out)


def _split_series(line: str) -> Optional[tuple]:
    """Split one exposition line into ``(series, value_text)`` where
    ``series`` is the metric name with its label block verbatim.

    Quote- and escape-aware: a label value legitimately containing ``"} "``
    (escaped quotes) must not fool the scan — the naive ``rpartition(" ")``
    this replaces silently dropped such lines."""
    brace = line.find("{")
    space = line.find(" ")
    if brace == -1 or (space != -1 and space < brace):
        name, _, value = line.partition(" ")
        value = value.strip()
        return (name, value) if name and value else None
    i = brace + 1
    n = len(line)
    in_quotes = False
    while i < n:
        c = line[i]
        if in_quotes:
            if c == "\\":
                i += 2
                continue
            if c == '"':
                in_quotes = False
        elif c == '"':
            in_quotes = True
        elif c == "}":
            value = line[i + 1 :].strip()
            return (line[: i + 1], value) if value else None
        i += 1
    return None  # unterminated label block


def parse_labels(series: str) -> tuple:
    """Parse a series name (as returned in :func:`parse_prometheus` keys)
    into ``(family, labels)`` with label values **unescaped** — the exact
    inverse of rendering, so ``render → parse`` round-trips any label value."""
    brace = series.find("{")
    if brace == -1:
        return series, {}
    fam = series[:brace]
    body = series[brace + 1 : series.rindex("}")]
    labels: Dict[str, str] = {}
    i, n = 0, len(body)
    while i < n:
        eq = body.find('="', i)
        if eq == -1:
            break
        key = body[i:eq]
        j = eq + 2
        start = j
        while j < n:
            c = body[j]
            if c == "\\":
                j += 2
                continue
            if c == '"':
                break
            j += 1
        labels[key] = _unescape_label(body[start:j])
        i = j + 1
        if i < n and body[i] == ",":
            i += 1
    return fam, labels


def parse_prometheus(text: str) -> Dict[str, float]:
    """Minimal exposition parser for tests/benchmarks scraping the endpoint:
    returns ``{metric_with_labels: value}`` (comments skipped). Label blocks
    are scanned quote/escape-aware, so label values containing spaces,
    braces or quotes parse correctly; feed a key to :func:`parse_labels` to
    recover the unescaped label values. Not a full grammar — good for
    exact-line lookups and float parsing."""
    out: Dict[str, float] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        split = _split_series(line)
        if split is None:
            continue
        name, value = split
        try:
            out[name] = float(value)
        except ValueError:
            continue
    return out


class MetricsExporter:
    """Serves ``render_prometheus(registry)`` over plain HTTP.

    ``port=0`` binds an ephemeral port (read it back from ``.port`` /
    ``.url``). The server thread is a daemon: it never blocks interpreter
    exit, and ``stop()`` shuts it down deterministically for tests.

    The endpoint has no auth, so a **bind-address guard** applies: binding a
    non-loopback ``host`` requires either an explicit ``allow_prefixes``
    allowlist (only matching metric families are served — see
    :func:`render_prometheus`) or ``allow_all=True`` (the operator's explicit
    "serve everything to the network" opt-in). Loopback binds stay
    unrestricted by default, exactly as before.
    """

    def __init__(
        self,
        registry: Optional[MetricRegistry] = None,
        host: str = "127.0.0.1",
        port: int = 0,
        allow_prefixes: Optional[Sequence[str]] = None,
        allow_all: bool = False,
    ) -> None:
        self.registry = registry if registry is not None else get_registry()
        self.allow_prefixes = tuple(allow_prefixes) if allow_prefixes is not None else None
        if host not in LOOPBACK_HOSTS and self.allow_prefixes is None and not allow_all:
            raise ValueError(
                f"refusing to serve every registry metric on non-loopback host {host!r}: "
                "pass allow_prefixes=(...) to allowlist metric families, or "
                "allow_all=True to explicitly opt in"
            )
        self._host = host
        self._want_port = port
        self._server: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- the collect() API (no HTTP) ---------------------------------------
    def collect(self) -> str:
        return render_prometheus(self.registry, allow_prefixes=self.allow_prefixes)

    @property
    def port(self) -> int:
        if self._server is None:
            raise RuntimeError("exporter not started")
        return self._server.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self._host}:{self.port}/metrics"

    def start(self) -> "MetricsExporter":
        exporter = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self) -> None:  # noqa: N802 — http.server API
                if self.path.split("?", 1)[0] not in ("/", "/metrics"):
                    self.send_error(404)
                    return
                body = exporter.collect().encode()
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args) -> None:  # scrapes are not log events
                pass

        self._server = ThreadingHTTPServer((self._host, self._want_port), Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(
            target=self._server.serve_forever, daemon=True, name="paio-metrics-exporter"
        )
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


def start_exporter(
    port: int = 0,
    host: str = "127.0.0.1",
    registry: Optional[MetricRegistry] = None,
    allow_prefixes: Optional[Sequence[str]] = None,
    allow_all: bool = False,
) -> MetricsExporter:
    """Convenience: build + start an exporter over the shared registry."""
    return MetricsExporter(
        registry=registry, host=host, port=port,
        allow_prefixes=allow_prefixes, allow_all=allow_all,
    ).start()
