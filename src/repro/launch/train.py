"""Production training driver.

Wires together every substrate: arch config → mesh + logical sharding →
pjit train step → PAIO-instrumented data pipeline (foreground flow) and
async checkpointing (background flow, DRL-limited) → TrainIOControl feedback
loop → heartbeat/straggler monitor. Designed so the same entry point runs a
CPU smoke test and a 512-chip pod (mesh shape from flags).

  PYTHONPATH=src python -m repro.launch.train --arch llama3_2_1b --steps 20 \
      --batch 8 --seq 128 --mesh 1x1 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, CheckpointManager, latest_step
from repro.core import (
    BG_CHECKPOINT,
    FG_FETCH,
    ControlPlane,
    DifferentiationRule,
    FlowSpec,
    HousekeepingRule,
    Stage,
    TrainIOControl,
)
from repro.data import DataPipeline, SyntheticTokenSource
from repro.distributed.sharding import sharding_rules
from repro.ft import HeartbeatMonitor
from repro.launch.mesh import make_mesh
from repro.launch.steps import (
    TrainConfig,
    build_train_step,
    init_train_state,
    make_state_shardings,
    rules_for,
)
from repro.optim import AdamWConfig, cosine_schedule
from repro.telemetry import StepTimer
import repro.configs as configs


def build_io_stage(total_bandwidth: float = 512e6) -> tuple[Stage, ControlPlane]:
    """One stage for the job's I/O stack: fg fetches + bg checkpoint writes."""
    stage = Stage("train-io")
    for ch in ("fetch", "ckpt"):
        stage.hsk_rule(HousekeepingRule(op="create_channel", channel=ch))
    stage.hsk_rule(
        HousekeepingRule(
            op="create_object", channel="ckpt", object_id="0", object_kind="drl",
            params={"rate": total_bandwidth * 0.3},
        )
    )
    stage.dif_rule(DifferentiationRule(channel="fetch", match={"request_context": FG_FETCH}))
    stage.dif_rule(DifferentiationRule(channel="ckpt", match={"request_context": BG_CHECKPOINT}))
    algo = TrainIOControl(
        fg=FlowSpec("train-io", "fetch"),
        background=[FlowSpec("train-io", "ckpt")],
        total_bandwidth=total_bandwidth,
        loop_interval=0.2,
    )
    cp = ControlPlane(algo)
    cp.register_stage(stage)
    return stage, cp


def train(
    arch: str,
    steps: int = 20,
    batch: int = 8,
    seq: int = 128,
    mesh_shape: tuple = (1, 1),
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    microbatches: int = 1,
    lr: float = 3e-4,
    resume: bool = False,
    log_every: int = 1,
    reduced: bool = False,
    host: str = "host0",
) -> list:
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    mesh = make_mesh(mesh_shape)
    rules = rules_for(cfg, batch_size=batch, mesh=mesh)

    stage, cp = build_io_stage()
    cp.start()
    monitor = HeartbeatMonitor(dead_after=600.0)
    pipeline = DataPipeline(
        SyntheticTokenSource(vocab=cfg.vocab, batch=batch, seq=seq), stage=stage
    )
    ckpt_mgr = ckpt = None
    if ckpt_dir:
        ckpt_mgr = CheckpointManager(ckpt_dir, stage=stage)
        ckpt = AsyncCheckpointer(ckpt_mgr)

    tcfg = TrainConfig(
        microbatches=microbatches,
        opt=AdamWConfig(lr=lr),
        lr_schedule=cosine_schedule(lr, warmup=max(steps // 10, 1), total=steps),
    )

    with mesh, sharding_rules(mesh, rules):
        state_shardings = make_state_shardings(cfg, mesh, rules)
        step_fn = jax.jit(
            build_train_step(cfg, tcfg),
            in_shardings=(state_shardings, None),
            out_shardings=(state_shardings, None),
            donate_argnums=0,
        )
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        start_step = 0
        if resume and ckpt_mgr is not None and (last := latest_step(ckpt_dir)) is not None:
            state = ckpt_mgr.restore(last, jax.eval_shape(lambda: state))
            start_step = last
            print(f"resumed from checkpoint step {last}")

        timer = StepTimer()
        losses = []
        for i in range(start_step, steps):
            tokens = pipeline.read_batch(i)
            timer.start()
            state, metrics = step_fn(state, {"tokens": jnp.asarray(tokens)})
            loss = float(metrics["loss"])
            dt = timer.stop()
            monitor.beat(host, dt)
            losses.append(loss)
            if i % log_every == 0:
                print(f"step {i:>5} loss {loss:.4f} grad_norm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0:
                ckpt.save(i + 1, state)
        if ckpt is not None:
            ckpt.wait()

    stats = stage.collect()
    print(
        "io stats:",
        {n: f"{s.cumulative_bytes/2**20:.1f}MiB" for n, s in stats.per_channel.items() if s.cumulative_bytes},
    )
    cp.stop()
    return losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1x1", help="e.g. 1x1, 4x2, 2x16x16")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="use the smoke-scale config")
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x"))
    train(
        args.arch,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        mesh_shape=mesh_shape,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        microbatches=args.microbatches,
        lr=args.lr,
        resume=args.resume,
        reduced=args.reduced,
    )


if __name__ == "__main__":
    main()
