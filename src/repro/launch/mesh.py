"""Production meshes.

``make_production_mesh`` builds the target deployment mesh: one v5e pod of
16×16 = 256 chips (axes ``data × model``), or two pods = 512 chips with a
leading ``pod`` axis. Functions (not module constants) so importing this
module never touches JAX device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* first use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Optional[Tuple[str, ...]] = None):
    """Arbitrary (data, model[, pod]) mesh for tests and small runs."""
    if axes is None:
        axes = ("data", "model")[: len(shape)] if len(shape) <= 2 else ("pod", "data", "model")
    return jax.make_mesh(shape, axes)


HW = {
    # TPU v5e, per chip
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bandwidth": 819e9,  # B/s
    "hbm_bytes": 16 * 1024**3,
    "ici_link_bandwidth": 50e9,  # B/s per link (one direction)
}
