"""Train / serve step builders with logical-axis sharding.

``build_train_step`` returns a jit-able ``(state, batch) → (state, metrics)``
with microbatch gradient accumulation (``lax.scan``), global-norm clipping and
a fused AdamW update. ``build_prefill_step``/``build_decode_step`` return the
serving-side functions operating on stacked per-segment caches.

``make_*_shardings`` translate the model's logical-axis trees into
``NamedSharding`` trees for a given mesh — the glue between model code and
``jax.jit(in_shardings=…)`` used by both the launcher and the multi-pod
dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import DEFAULT_RULES, logical_to_spec, sharding_rules
from repro.models import model as model_lib
from repro.models.model import ArchConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update

PyTree = Any

#: per-arch logical-rule overrides (divisibility: heads % model_axis etc.)
ARCH_RULES: Dict[str, Dict[str, Any]] = {
    "hymba-1.5b": {"heads": None, "kv_heads": None},  # 25 heads don't split by 16
    "xlstm-350m": {"heads": None, "kv_heads": None},  # 4 heads; inner dim shards via "ff"
}

#: kv heads are replicated under TP by default (Megatron-style) — most assigned
#: archs have n_kv < 16. The decode KV cache shards its *sequence* dim instead.
BASE_RULES = {"kv_heads": None}


def rules_for(cfg: ArchConfig, *, decode: bool = False, batch_size: Optional[int] = None, mesh: Optional[Mesh] = None):
    rules = dict(BASE_RULES)
    rules.update(ARCH_RULES.get(cfg.name, {}))
    if decode:
        rules["kv_seq"] = "model"  # sequence-parallel KV cache (flash-decoding style)
    if batch_size is not None and mesh is not None:
        dp = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                dp *= mesh.shape[ax]
        if batch_size % dp != 0:  # e.g. long_500k batch=1
            rules["batch"] = None
            rules["expert_group"] = None
    return rules


def _tree_shardings(mesh: Mesh, axes_tree: PyTree, rules: Dict[str, Any]) -> PyTree:
    def is_axes(v):
        return isinstance(v, tuple) and all(e is None or isinstance(e, str) for e in v)

    with sharding_rules(mesh, rules):
        return jax.tree_util.tree_map(
            lambda ax: NamedSharding(mesh, logical_to_spec(ax)), axes_tree, is_leaf=is_axes
        )


# --------------------------------------------------------------------------- #
# sharding trees                                                               #
# --------------------------------------------------------------------------- #
def make_param_shardings(cfg: ArchConfig, mesh: Mesh, rules: Optional[Dict[str, Any]] = None) -> PyTree:
    return _tree_shardings(mesh, model_lib.param_logical_axes(cfg), rules or rules_for(cfg))


def make_state_shardings(cfg: ArchConfig, mesh: Mesh, rules: Optional[Dict[str, Any]] = None) -> Dict[str, PyTree]:
    p = make_param_shardings(cfg, mesh, rules)
    return {
        "params": p,
        "opt": {"m": p, "v": p, "step": NamedSharding(mesh, P())},
    }


def make_batch_shardings(cfg: ArchConfig, mesh: Mesh, specs: Dict[str, Any], rules: Dict[str, Any]) -> Dict[str, Any]:
    axes = {}
    for name, spec in specs.items():
        if name in ("tokens", "labels", "loss_mask", "positions"):
            axes[name] = ("batch", None)
        elif name == "frames":
            axes[name] = ("batch", None, None)
        elif name == "vision_embeds":
            axes[name] = ("batch", None, None)
        else:
            axes[name] = tuple([None] * len(spec.shape))
    return _tree_shardings(mesh, axes, rules)


def make_cache_shardings(cfg: ArchConfig, mesh: Mesh, rules: Dict[str, Any]) -> PyTree:
    return _tree_shardings(mesh, model_lib.cache_logical_axes(cfg), rules)


# --------------------------------------------------------------------------- #
# train step                                                                   #
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    opt: AdamWConfig = AdamWConfig()
    lr_schedule: Optional[Callable] = None
    #: unroll the microbatch loop (cost probes — while bodies are counted once
    #: by XLA cost analysis, so probes difference unrolled variants)
    unroll_micro: bool = False
    #: compute grads + grad_norm but skip the optimizer update (cost probes
    #: separate per-layer gradient cost from per-layer optimizer cost)
    grad_only: bool = False


def init_train_state(cfg: ArchConfig, key) -> Dict[str, PyTree]:
    params = model_lib.init_params(cfg, key)
    return {"params": params, "opt": adamw_init(params)}


def build_train_step(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()) -> Callable:
    """(state, batch) → (state, metrics). Microbatch accumulation over the
    leading batch axis; grads averaged in fp32."""

    def loss_for(params, mb):
        loss, metrics = model_lib.loss_fn(cfg, params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for, has_aux=True)

    def train_step(state: Dict[str, PyTree], batch: Dict[str, jax.Array]):
        params = state["params"]
        n_micro = tcfg.microbatches
        if n_micro <= 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:

            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree_util.tree_map(split, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                (l, _m), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b_: a + b_.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            carry0 = (g0, jnp.zeros((), jnp.float32))
            if tcfg.unroll_micro:
                carry = carry0
                for i in range(n_micro):
                    carry, _ = acc(carry, jax.tree_util.tree_map(lambda a: a[i], micro))
                grads, loss_sum = carry
            else:
                (grads, loss_sum), _ = jax.lax.scan(acc, carry0, micro)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {"loss": loss}

        if tcfg.grad_only:
            from repro.optim import global_norm

            return state, {"loss": loss, "grad_norm": global_norm(grads)}
        lr = tcfg.lr_schedule(state["opt"]["step"]) if tcfg.lr_schedule else None
        new_params, new_opt, opt_metrics = adamw_update(params, grads, state["opt"], tcfg.opt, lr)
        out_metrics = {"loss": loss, **opt_metrics}
        if lr is not None:
            out_metrics["lr"] = lr
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


# --------------------------------------------------------------------------- #
# serve steps                                                                  #
# --------------------------------------------------------------------------- #
def build_prefill_step(cfg: ArchConfig) -> Callable:
    def prefill(params, caches, batch):
        logits, _, new_caches = model_lib.forward(cfg, params, batch, caches=caches, update_cache=True)
        logits = model_lib.mask_padded_vocab(cfg, logits)
        next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return prefill


def build_decode_step(cfg: ArchConfig) -> Callable:
    def decode(params, caches, batch):
        logits, _, new_caches = model_lib.forward(cfg, params, batch, caches=caches, update_cache=True)
        logits = model_lib.mask_padded_vocab(cfg, logits)
        next_token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_token, new_caches

    return decode


def build_encoder_step(cfg: ArchConfig) -> Callable:
    """Encoder-only inference (hubert): frames → frame logits."""

    def encode(params, batch):
        logits, _, _ = model_lib.forward(cfg, params, batch)
        return model_lib.mask_padded_vocab(cfg, logits)

    return encode
