"""Compiled-artifact analysis: collective bytes from HLO text + roofline terms.

``cost_analysis`` gives HLO FLOPs and bytes-accessed; collective traffic is
not in there, so we parse the (SPMD, per-device) optimized HLO and sum the
result sizes of every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute``, converting each to an estimated
*bytes moved per device* with a ring cost model:

  all-reduce       2 · size · (n-1)/n      (reduce-scatter + all-gather)
  all-gather       size · (n-1)/n          (size = gathered result)
  reduce-scatter   size · (n-1)            (size = scattered result; input n×)
  all-to-all       size · (n-1)/n
  collective-permute  size

The per-device program's collective bytes divided by the per-link bandwidth is
the collective roofline term (equivalent to global_bytes / (chips · link_bw)).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# e.g.  %x = f32[8,128]{1,0} all-reduce(...), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,n]<=[N]
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    counts: Dict[str, int]
    result_bytes: Dict[str, int]
    moved_bytes: Dict[str, float]

    @property
    def total_moved(self) -> float:
        return sum(self.moved_bytes.values())


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    counts = {c: 0 for c in _COLLECTIVES}
    result_bytes = {c: 0 for c in _COLLECTIVES}
    moved = {c: 0.0 for c in _COLLECTIVES}
    seen_start: set = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:  # async pair: count the start only
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        size = _shape_bytes(m.group("shape"))
        n = max(_group_size(line, default_group), 1)
        counts[op] += 1
        result_bytes[op] += size
        if op == "all-reduce":
            moved[op] += 2.0 * size * (n - 1) / n
        elif op == "all-gather":
            moved[op] += size * (n - 1) / n
        elif op == "reduce-scatter":
            moved[op] += size * (n - 1)
        elif op == "all-to-all":
            moved[op] += size * (n - 1) / n
        else:  # collective-permute
            moved[op] += size
    return CollectiveStats(counts=counts, result_bytes=result_bytes, moved_bytes=moved)


# --------------------------------------------------------------------------- #
# roofline                                                                     #
# --------------------------------------------------------------------------- #
@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (SPMD program) quantities
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    # terms in seconds
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_flops_ratio: float
    memory_per_device_bytes: float = 0.0
    note: str = ""

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    collectives: CollectiveStats,
    model_flops_global: float,
    hw: Dict[str, float],
    memory_per_device: float = 0.0,
    note: str = "",
) -> Roofline:
    flops = float(cost.get("flops", 0.0))  # per-device (SPMD module)
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    coll = collectives.total_moved
    compute_s = flops / hw["peak_flops_bf16"]
    memory_s = bytes_accessed / hw["hbm_bandwidth"]
    collective_s = coll / hw["ici_link_bandwidth"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    model_flops_dev = model_flops_global / chips
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=bytes_accessed,
        collective_bytes=coll,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops=model_flops_dev,
        useful_flops_ratio=(model_flops_dev / flops) if flops else 0.0,
        memory_per_device_bytes=memory_per_device,
        note=note,
    )
