"""The (architecture × input-shape) cell matrix for the dry-run.

Shapes (assigned):
  train_4k     seq 4,096   global_batch 256   → train_step
  prefill_32k  seq 32,768  global_batch 32    → serve (prefill / encoder fwd)
  decode_32k   seq 32,768  global_batch 128   → serve (1 token, 32k cache)
  long_500k    seq 524,288 global_batch 1     → serve (1 token, 500k state)

Skips (recorded in DESIGN.md §Shape-cell skips):
  * long_500k only for sub-quadratic archs (ssm / hybrid);
  * decode shapes skipped for encoder-only (hubert).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro.configs import ALL_ARCHS, get as get_config
from repro.models.model import ArchConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC = {"hymba_1_5b", "xlstm_350m"}
ENCODER_ONLY = {"hubert_xlarge"}


def skip_reason(arch: str, shape: str) -> Optional[str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "long_500k needs sub-quadratic attention (full-attention arch)"
    if shape in ("decode_32k", "long_500k") and arch in ENCODER_ONLY:
        return "encoder-only arch has no autoregressive decode step"
    return None


def all_cells() -> List[Tuple[str, str]]:
    cells = []
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            if skip_reason(arch, shape) is None:
                cells.append((arch, shape))
    return cells


def tune_for_cell(cfg: ArchConfig, cell: ShapeCell, dp: int) -> Tuple[ArchConfig, int]:
    """Execution config for one cell: attention backend, remat, microbatches."""
    params_b = cfg.total_params() / 1e9
    if cell.kind == "train":
        target_mb = 8 if params_b > 10 else 4  # §Perf C2: fewer micros → fewer per-micro weight gathers + grad reductions
        microbatches = max(min(target_mb, cell.global_batch // max(dp, 1)), 1)
        cfg = cfg.replace(attn_backend="chunked", attn_chunk=2048, remat=True, mlstm_chunk=512)
    elif cell.kind == "prefill":
        microbatches = 1
        # wider mLSTM chunks bound the probes' unrolled-body count at 32k
        cfg = cfg.replace(attn_backend="chunked", attn_chunk=2048, mlstm_chunk=2048)
    else:  # decode: q_len=1 — naive core over the cache is the right shape
        microbatches = 1
        cfg = cfg.replace(attn_backend="xla")
    # MoE dispatch-group sizing: keep the [G,S',E,C] tensor bounded
    if cfg.n_experts > 0:
        cfg = cfg.replace(moe_group_size=256 if cell.kind == "train" else 512)
    return cfg, microbatches


def model_flops_for_cell(cfg: ArchConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens processed.

    Train counts fwd+bwd (6·N·D); serve steps count 2·N·D. Attention
    quadratic FLOPs are excluded by definition (this is the *useful* model
    FLOPs yardstick the roofline ratio asks for).
    """
    n_layers_active = cfg.active_params_per_layer() * cfg.n_layers
    embed = cfg.vocab_padded * cfg.d_model  # lm head matmul params
    n_active = n_layers_active + embed
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq
        return 2.0 * n_active * tokens
    tokens = cell.global_batch * 1  # decode: one token per sequence
    return 2.0 * n_active * tokens
