import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract memory / cost / collective analyses.

The two lines above MUST run before any jax import: they give the CPU host
512 placeholder devices so ``jax.make_mesh`` can build the 16×16 (single-pod)
and 2×16×16 (multi-pod) production meshes. ``.lower().compile()`` proves the
sharding config is coherent (no mismatched shardings, no OOM at compile, all
collectives supported); no arrays are ever materialized.

Cost correction: XLA's ``cost_analysis`` counts ``while``-loop bodies ONCE
(verified empirically), so the scan-over-layers/microbatches program
undercounts FLOPs. We therefore compile small *probe* variants — unrolled
loops, one microbatch (global_batch/M), 1 vs 2 layers per segment kind, with
and without the optimizer — and difference them:

  per-layer grad  g_k = G_k − G0          (grad-only probes)
  per-layer opt   o_k = (P_k − P0) − g_k  (full-step probes)
  train total ≈ M·[G0 + Σ_k (L_k−1)·g_k] + (P0−G0) + Σ_k (L_k−1)·o_k
  serve total ≈ P0 + Σ_k (L_k−1)·(P_k−P0)

(sLSTM's time recurrence stays a while loop, so its per-layer diff is scaled
by S analytically — ≲25% overcount on 3/24 xlstm layers, documented.) The
real scanned program is still compiled for ``memory_analysis`` (what must fit
in HBM) and to prove the production sharding lowers.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_2_1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]

Results land in experiments/dryrun/<arch>_<shape>_<mesh>.json.
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import ALL_ARCHS, get as get_config
from repro.data.pipeline import make_batch_specs
from repro.distributed.sharding import sharding_rules
from repro.launch import cells as cells_lib
from repro.launch.analysis import parse_collectives, roofline_terms
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import (
    TrainConfig,
    build_decode_step,
    build_encoder_step,
    build_prefill_step,
    build_train_step,
    init_train_state,
    make_batch_shardings,
    make_cache_shardings,
    make_state_shardings,
    rules_for,
)
from repro.models import model as model_lib


def _lower_cell(cfg, cell, mesh, rules, microbatches: int, unroll_micro: bool = False, grad_only: bool = False):
    """Lower one cell variant; returns the lowered computation."""
    with mesh, sharding_rules(mesh, rules):
        batch_specs = make_batch_specs(cfg, cell.global_batch, cell.seq, cell.kind)
        batch_shardings = make_batch_shardings(cfg, mesh, batch_specs, rules)
        abstract_params = jax.eval_shape(lambda: model_lib.init_params(cfg, jax.random.PRNGKey(0)))

        if cell.kind == "train":
            state_shardings = make_state_shardings(cfg, mesh, rules)
            abstract_state = jax.eval_shape(lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
            step = build_train_step(
                cfg, TrainConfig(microbatches=microbatches, unroll_micro=unroll_micro, grad_only=grad_only)
            )
            jitted = jax.jit(
                step,
                in_shardings=(state_shardings, batch_shardings),
                out_shardings=(state_shardings, None),
                donate_argnums=0,
            )
            return jitted.lower(abstract_state, batch_specs)

        param_shardings = make_state_shardings(cfg, mesh, rules)["params"]
        if cfg.family == "audio":
            step = build_encoder_step(cfg)
            jitted = jax.jit(step, in_shardings=(param_shardings, batch_shardings))
            return jitted.lower(abstract_params, batch_specs)

        abstract_caches = jax.eval_shape(
            lambda: model_lib.init_caches(cfg, cell.global_batch, cell.seq, dtype=jnp.bfloat16)
        )
        cache_shardings = make_cache_shardings(cfg, mesh, rules)
        step = build_decode_step(cfg) if cell.kind == "decode" else build_prefill_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(param_shardings, cache_shardings, batch_shardings),
            out_shardings=(None, cache_shardings),
            donate_argnums=1,
        )
        return jitted.lower(abstract_params, abstract_caches, batch_specs)


def _cost_of(compiled) -> Dict[str, float]:
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text(), default_group=2)
    return {
        "flops": float(cost.get("flops", 0.0) or 0.0),
        "bytes": float(cost.get("bytes accessed", 0.0) or 0.0),
        "coll": float(coll.total_moved),
        "_coll_counts": coll.counts,
    }


def _combine(a: Dict[str, float], b: Dict[str, float], fa: float, fb: float) -> Dict[str, float]:
    return {k: fa * a.get(k, 0.0) + fb * b.get(k, 0.0) for k in ("flops", "bytes", "coll")}


def _probe_costs(cfg, cell, mesh, rules, microbatches: int) -> Tuple[Dict[str, float], Dict[str, Any]]:
    """Difference unrolled probes into corrected per-step cost totals."""
    segs = cfg.segments()
    kinds: List[str] = []
    layer_counts: Dict[str, int] = {}
    for kind, count in segs:
        layer_counts[kind] = layer_counts.get(kind, 0) + count
        if kind not in kinds:
            kinds.append(kind)

    def probe_cfg(counts: Tuple[Tuple[str, int], ...]):
        return cfg.replace(segment_override=counts, unroll_layers=True, unroll_scans=True)

    # probes see one microbatch worth of tokens
    if cell.kind == "train" and microbatches > 1:
        probe_cell = cells_lib.ShapeCell(cell.name, cell.seq, cell.global_batch // microbatches, cell.kind)
    else:
        probe_cell = cell

    base_counts = tuple((kk, 1) for kk in kinds)

    def probe(counts, grad_only, cell_override=None):
        lowered = _lower_cell(
            probe_cfg(counts), cell_override or probe_cell, mesh, rules, 1, grad_only=grad_only
        )
        return _cost_of(lowered.compile())

    def slstm_per_layer(grad_only) -> Dict[str, float]:
        """sLSTM is a true time recurrence: its per-layer cost is measured by
        fully time-unrolled mini-probes (seq 64 vs 32, 1 vs 2 layers) — every
        quantity in the block is per-token, so per-layer(S) = diff · S/32."""
        s_tokens = 1 if cell.kind == "decode" else cell.seq
        if cell.kind == "decode":
            # decode is a single step — the plain layer diff is already exact
            return None
        costs = {}
        for n_layers in (1, 2):
            for seq in (32, 64):
                mini = cells_lib.ShapeCell(cell.name, seq, probe_cell.global_batch, cell.kind)
                costs[(n_layers, seq)] = probe((("slstm", n_layers),), grad_only, cell_override=mini)
        marginal = _combine(
            _combine(costs[(2, 64)], costs[(2, 32)], 1.0, -1.0),
            _combine(costs[(1, 64)], costs[(1, 32)], 1.0, -1.0),
            1.0,
            -1.0,
        )
        return {kk: v * (s_tokens / 32.0) for kk, v in marginal.items()}

    p0 = probe(base_counts, grad_only=False)
    per_layer_full: Dict[str, Dict[str, float]] = {}
    fix_once_full: Dict[str, Dict[str, float]] = {}
    for k in kinds:
        counts = tuple((kk, 2 if kk == k else 1) for kk in kinds)
        plain = _combine(probe(counts, False), p0, 1.0, -1.0)
        per_layer_full[k] = plain
        if k == "slstm" and cell.kind != "decode":
            per_layer_full[k] = slstm_per_layer(grad_only=False)
            # P0 embeds one scan-undercounted sLSTM layer: swap its cost
            fix_once_full[k] = _combine(per_layer_full[k], plain, 1.0, -1.0)

    if cell.kind != "train" or microbatches <= 1:
        total = dict(p0)
        for k in kinds:
            total = _combine(total, per_layer_full[k], 1.0, layer_counts[k] - 1)
            if k in fix_once_full:
                total = _combine(total, fix_once_full[k], 1.0, 1.0)
        detail = {
            "p0": {kk: v for kk, v in p0.items() if not kk.startswith("_")},
            "per_layer": per_layer_full,
            "layer_counts": layer_counts,
            "microbatches": 1,
        }
        return total, detail

    # train with microbatching: separate grad cost (×M) from optimizer (×1)
    g0 = probe(base_counts, grad_only=True)
    per_layer_grad: Dict[str, Dict[str, float]] = {}
    fix_once_grad: Dict[str, Dict[str, float]] = {}
    for k in kinds:
        counts = tuple((kk, 2 if kk == k else 1) for kk in kinds)
        plain = _combine(probe(counts, True), g0, 1.0, -1.0)
        per_layer_grad[k] = plain
        if k == "slstm" and cell.kind != "decode":
            per_layer_grad[k] = slstm_per_layer(grad_only=True)
            fix_once_grad[k] = _combine(per_layer_grad[k], plain, 1.0, -1.0)

    grad_total = dict(g0)
    for k in kinds:
        grad_total = _combine(grad_total, per_layer_grad[k], 1.0, layer_counts[k] - 1)
        if k in fix_once_grad:
            grad_total = _combine(grad_total, fix_once_grad[k], 1.0, 1.0)
    opt_total = _combine(p0, g0, 1.0, -1.0)
    for k in kinds:
        o_k = _combine(per_layer_full[k], per_layer_grad[k], 1.0, -1.0)
        opt_total = _combine(opt_total, o_k, 1.0, layer_counts[k] - 1)
    total = _combine(grad_total, opt_total, float(microbatches), 1.0)
    detail = {
        "p0": {kk: v for kk, v in p0.items() if not kk.startswith("_")},
        "g0": {kk: v for kk, v in g0.items() if not kk.startswith("_")},
        "per_layer_grad": per_layer_grad,
        "per_layer_opt": {k: _combine(per_layer_full[k], per_layer_grad[k], 1.0, -1.0) for k in kinds},
        "layer_counts": layer_counts,
        "microbatches": microbatches,
    }
    return total, detail


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str = "experiments/dryrun") -> Dict[str, Any]:
    cell = cells_lib.SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    chips = mesh.size
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)

    cfg = get_config(arch)
    cfg, microbatches = cells_lib.tune_for_cell(cfg, cell, dp)
    rules = rules_for(cfg, decode=(cell.kind == "decode"), batch_size=cell.global_batch, mesh=mesh)

    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "chips": chips,
        "kind": cell.kind,
        "microbatches": microbatches,
        "params": int(cfg.total_params()),
    }

    # 1) the real scanned program: proves sharding + memory fit
    t0 = time.monotonic()
    lowered = _lower_cell(cfg, cell, mesh, rules, microbatches)
    result["lower_s"] = round(time.monotonic() - t0, 1)
    t1 = time.monotonic()
    compiled = lowered.compile()
    result["compile_s"] = round(time.monotonic() - t1, 1)
    mem = compiled.memory_analysis()
    mem_stats = {
        k: int(getattr(mem, k, 0) or 0)
        for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes", "generated_code_size_in_bytes")
    }
    per_device_bytes = mem_stats["argument_size_in_bytes"] + mem_stats["temp_size_in_bytes"]
    raw_cost = _cost_of(compiled)
    result["memory"] = mem_stats
    result["memory_per_device_gib"] = round(per_device_bytes / 2**30, 3)
    result["collective_counts_scanned_hlo"] = raw_cost["_coll_counts"]

    # 2) probe-corrected cost totals
    t2 = time.monotonic()
    total, detail = _probe_costs(cfg, cell, mesh, rules, microbatches)
    result["probe_s"] = round(time.monotonic() - t2, 1)
    result["cost"] = total
    result["cost_detail"] = detail

    model_flops = cells_lib.model_flops_for_cell(cfg, cell)
    from repro.launch.analysis import CollectiveStats

    rf = roofline_terms(
        arch=arch,
        shape=shape,
        mesh_name=mesh_name,
        chips=chips,
        cost={"flops": total["flops"], "bytes accessed": total["bytes"]},
        collectives=CollectiveStats(counts={}, result_bytes={}, moved_bytes={"total": total["coll"]}),
        model_flops_global=model_flops,
        hw=HW,
        memory_per_device=per_device_bytes,
    )
    result["roofline"] = {
        "compute_s": rf.compute_s,
        "memory_s": rf.memory_s,
        "collective_s": rf.collective_s,
        "dominant": rf.dominant,
        "model_flops_per_device": rf.model_flops,
        "useful_flops_ratio": rf.useful_flops_ratio,
    }

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}_{shape}_{mesh_name}.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.all:
        cell_list = cells_lib.all_cells()
    elif args.arch and args.shape:
        reason = cells_lib.skip_reason(args.arch, args.shape)
        if reason:
            print(f"SKIP {args.arch} × {args.shape}: {reason}")
            return 0
        cell_list = [(args.arch, args.shape)]
    else:
        ap.error("--arch and --shape, or --all")
        return 2

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    failures = 0
    for arch, shape in cell_list:
        for mesh_name in meshes:
            tag = f"{arch} × {shape} × {mesh_name}"
            out_file = os.path.join(args.out, f"{arch}_{shape}_{mesh_name}.json")
            if args.skip_existing and os.path.exists(out_file):
                print(f"SKIP {tag} (exists)")
                continue
            try:
                r = run_cell(arch, shape, mesh_name, args.out)
                rf = r["roofline"]
                print(
                    f"OK   {tag}: mem/dev={r['memory_per_device_gib']:.2f}GiB "
                    f"compute={rf['compute_s']*1e3:.2f}ms memory={rf['memory_s']*1e3:.2f}ms "
                    f"collective={rf['collective_s']*1e3:.2f}ms dominant={rf['dominant']} "
                    f"useful={rf['useful_flops_ratio']:.2f} "
                    f"(compile {r['compile_s']}s probes {r['probe_s']}s)",
                    flush=True,
                )
            except Exception:
                failures += 1
                print(f"FAIL {tag}\n{traceback.format_exc()}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
