"""Policy runtime: installed-policy store + metrics bridge + trigger loop.

The runtime is owned by the control plane and driven from its feedback loop:
every ``collect`` tick the runtime (1) converts stage statistics into metric
gauges in the :class:`~repro.telemetry.metrics.MetricRegistry` (under
``<stage>.<channel>.<field>`` and ``<stage>.<field>`` names), (2) takes one
coherent registry sample — picking up any custom metrics other subsystems
registered — and (3) feeds the trigger engine, returning the wire rules for
whatever fired or released. The control plane ships those rules through its
stage handles, so triggers behave identically for embedded and UDS stages.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Mapping, Optional

from repro.core.stats import StageStats
from repro.telemetry.metrics import MetricRegistry

from .compile import CompiledPolicy
from .triggers import TriggerEngine, TriggerEvent


def stats_to_samples(stats: Mapping[str, StageStats]) -> Dict[str, float]:
    """Flatten per-stage statistics into metric gauges.

    Per channel: ``<stage>.<channel>.{throughput,iops,wait_ms,inflight,ops,bytes}``.
    Per stage (aggregates): ``<stage>.{throughput,iops,wait_ms,inflight,ops,bytes}``
    with ``wait_ms`` ops-weighted across channels.
    """
    out: Dict[str, float] = {}
    for stage, st in stats.items():
        tot_ops = tot_bytes = 0
        tot_tput = tot_iops = tot_wait = 0.0
        tot_inflight = 0
        for name, snap in st.per_channel.items():
            prefix = f"{stage}.{name}."
            out[prefix + "throughput"] = snap.throughput
            out[prefix + "iops"] = snap.iops
            out[prefix + "wait_ms"] = snap.mean_wait_ms
            out[prefix + "inflight"] = float(snap.inflight)
            out[prefix + "ops"] = float(snap.ops)
            out[prefix + "bytes"] = float(snap.bytes)
            tot_ops += snap.ops
            tot_bytes += snap.bytes
            tot_tput += snap.throughput
            tot_iops += snap.iops
            tot_wait += snap.wait_seconds
            tot_inflight += snap.inflight
        out[f"{stage}.throughput"] = tot_tput
        out[f"{stage}.iops"] = tot_iops
        out[f"{stage}.wait_ms"] = (tot_wait / tot_ops) * 1e3 if tot_ops else 0.0
        out[f"{stage}.inflight"] = float(tot_inflight)
        out[f"{stage}.ops"] = float(tot_ops)
        out[f"{stage}.bytes"] = float(tot_bytes)
    return out


class PolicyRuntime:
    """Installed policies + the trigger engine, one per control plane."""

    def __init__(self, registry: Optional[MetricRegistry] = None) -> None:
        self.registry = registry or MetricRegistry()
        self.trigger_engine = TriggerEngine()
        self._policies: Dict[str, CompiledPolicy] = {}
        self._stats_keys: set = set()  # gauges owned by the last stats tick
        self._lock = threading.Lock()

    # -- lifecycle ---------------------------------------------------------
    def install(self, compiled: CompiledPolicy) -> None:
        with self._lock:
            if compiled.name in self._policies:
                raise ValueError(f"policy {compiled.name!r} already installed")
            self._policies[compiled.name] = compiled
        for trigger in compiled.triggers:
            self.trigger_engine.add(trigger)

    def remove(self, name: str):
        """Uninstall ``name``; returns ``(compiled, fired)`` where ``fired``
        are the triggers that were FIRED at removal (popped atomically from
        the engine, so the control loop cannot release them concurrently) —
        callers apply their release rules so fired enforcement state does not
        outlive the policy."""
        with self._lock:
            compiled = self._policies.pop(name, None)
        if compiled is None:
            raise KeyError(f"policy {name!r} is not installed")
        fired = self.trigger_engine.remove_policy(name)
        return compiled, fired

    def get(self, name: str) -> Optional[CompiledPolicy]:
        with self._lock:
            return self._policies.get(name)

    def list(self) -> List[Dict[str, Any]]:
        with self._lock:
            policies = list(self._policies.values())
        states = self.trigger_engine.states()
        out = []
        for cp in policies:
            summary = cp.summary()
            summary["trigger_states"] = {
                t.qualified_name: states.get(t.qualified_name, "armed") for t in cp.triggers
            }
            summary["algorithms"] = type(cp.algorithm).__name__ if cp.algorithm else None
            out.append(summary)
        return out

    def algorithms(self) -> List[Any]:
        with self._lock:
            return [cp.algorithm for cp in self._policies.values() if cp.algorithm is not None]

    def pinned_targets(self) -> set:
        """Targets owned by currently-fired triggers — see
        :meth:`TriggerEngine.pinned_targets`."""
        return self.trigger_engine.pinned_targets()

    def __len__(self) -> int:
        with self._lock:
            return len(self._policies)

    # -- feedback-loop tick ------------------------------------------------
    def on_collect(
        self, now: float, stats: Mapping[str, StageStats]
    ) -> List[TriggerEvent]:
        """One tick: push stats into the registry, sample, evaluate triggers.

        Stage gauges are replaced wholesale each tick: a channel that
        disappeared (policy teardown, stage removal) takes its gauges with it,
        so triggers see the metric as *absent* (state frozen) rather than as
        a stale constant. Returns the trigger transitions; the caller applies
        each event's ``rules`` (stage → wire rules) through its stage handles.
        """
        gauges = stats_to_samples(stats)
        for stale in self._stats_keys - set(gauges):
            self.registry.unregister(stale)
        self._stats_keys = set(gauges)
        for key, value in gauges.items():
            self.registry.set_gauge(key, value)
        samples = self.registry.sample()
        return self.trigger_engine.observe(now, samples)
