"""Policy runtime: installed-policy store + metrics bridge + trigger loop.

The runtime is owned by the control plane and driven from its feedback loop:
every ``collect`` tick the runtime (1) converts stage statistics into metric
gauges in the shared :class:`~repro.telemetry.metrics.MetricRegistry` (under
``<stage>.<channel>.<field>`` and ``<stage>.<field>`` names, with export
descriptors so the exporter renders them as ``paio_channel_*`` /
``paio_stage_*`` families), (2) takes one coherent registry sample — picking
up any custom metrics other subsystems registered — and (3) feeds the trigger
engine, returning the wire rules for whatever fired or released. The control
plane ships those rules through its stage handles, so triggers behave
identically for embedded and UDS stages.

Installed policies are **versioned**: every install or atomic replace bumps a
runtime-wide monotonic version counter, surfaced in ``list()`` and exported
as ``paio_policy_version{policy=...}``; trigger fired/armed state exports as
``paio_trigger_fired{policy=...,trigger=...}`` so protective actions are
observable from outside the process.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core.stats import StageStats, fleet_view
from repro.telemetry.histogram import NBUCKETS, quantile_from_counts
from repro.telemetry.metrics import MetricRegistry, get_registry

from .compile import FLEET_STAGE, CompiledPolicy
from .triggers import TriggerEngine, TriggerEvent

#: per-channel StatsSnapshot fields published as gauges
CHANNEL_FIELDS = (
    "throughput", "iops", "wait_ms", "inflight", "ops", "bytes",
    "wait_p50_ms", "wait_p95_ms", "wait_p99_ms",
)

#: extras keys carrying sparse trace-filter wait-histogram buckets — folded
#: into percentile gauges, never published raw
_TRACE_HIST_PREFIX = "trace.wait_hist."


def _extras_to_samples(out: Dict[str, float], prefix: str, extras: Mapping[str, float]) -> None:
    """Publish one channel's filter-plane ``extras`` window counters under
    ``<prefix><key>`` and derive the control-plane-side ratios:

    * ``cache.hit_rate`` = hits / (hits + misses) — **omitted** when the
      window saw no lookups, so trigger windows freeze instead of reading a
      phantom 0.0 from an idle tenant,
    * ``compress.ratio`` = out_bytes / raw_bytes (omitted when idle),
    * sparse ``trace.wait_hist.<i>`` buckets fold into
      ``trace.wait_p{50,95,99}_ms`` (the buckets themselves are not
      published — they are transport, not signal).

    Extras are summable raw counters, so the same derivations are honest on
    merged (sharded / fleet-view) snapshots.
    """
    hist: Optional[List[int]] = None
    for k, v in extras.items():
        if k.startswith(_TRACE_HIST_PREFIX):
            if hist is None:
                hist = [0] * NBUCKETS
            try:
                idx = int(k[len(_TRACE_HIST_PREFIX):])
            except ValueError:
                continue
            if 0 <= idx < NBUCKETS:
                hist[idx] = int(v)
            continue
        out[prefix + k] = v
    hits = extras.get("cache.hits")
    misses = extras.get("cache.misses")
    if hits is not None or misses is not None:
        total = (hits or 0.0) + (misses or 0.0)
        if total > 0:
            out[prefix + "cache.hit_rate"] = (hits or 0.0) / total
    raw = extras.get("compress.raw_bytes")
    if raw:
        out[prefix + "compress.ratio"] = extras.get("compress.out_bytes", 0.0) / raw
    if hist is not None and any(hist):
        out[prefix + "trace.wait_p50_ms"] = quantile_from_counts(hist, 0.5)
        out[prefix + "trace.wait_p95_ms"] = quantile_from_counts(hist, 0.95)
        out[prefix + "trace.wait_p99_ms"] = quantile_from_counts(hist, 0.99)


class _StatKeys:
    """Pre-built gauge key strings for one (stage, channel) — the per-tick
    f-string churn at O(stages × channels × fields) was the allocator hot
    spot of the 50 ms control loop (ROADMAP PR-2 lever)."""

    __slots__ = CHANNEL_FIELDS

    def __init__(self, prefix: str) -> None:
        for f in CHANNEL_FIELDS:
            setattr(self, f, prefix + f)


def stats_to_samples(
    stats: Mapping[str, StageStats],
    out: Optional[Dict[str, float]] = None,
    key_cache: Optional[Dict[Tuple[str, Optional[str]], _StatKeys]] = None,
) -> Dict[str, float]:
    """Flatten per-stage statistics into metric gauges.

    Per channel: ``<stage>.<channel>.{throughput,iops,wait_ms,inflight,ops,
    bytes,wait_p50_ms,wait_p95_ms,wait_p99_ms}`` plus any filter-plane
    extras and their derived ratios (:func:`_extras_to_samples`). Per stage
    (aggregates):
    the same fields under ``<stage>.<field>`` with ``wait_ms`` ops-weighted
    and the wait percentiles taken as the max across channels (a conservative
    tail bound — exact cross-channel percentiles are not mergeable).

    ``out`` and ``key_cache`` let a steady-state caller (the policy runtime's
    50 ms loop) reuse its sample dict and key strings instead of reallocating
    one dict plus hundreds of f-strings per tick; both default to fresh
    objects so one-shot calls behave as before.
    """
    out = {} if out is None else out
    out.clear()
    cache = {} if key_cache is None else key_cache
    for stage, st in stats.items():
        tot_ops = tot_bytes = 0
        tot_tput = tot_iops = tot_wait = 0.0
        tot_inflight = 0
        max_p50 = max_p95 = max_p99 = 0.0
        for name, snap in st.per_channel.items():
            keys = cache.get((stage, name))
            if keys is None:
                keys = cache[(stage, name)] = _StatKeys(f"{stage}.{name}.")
            out[keys.throughput] = snap.throughput
            out[keys.iops] = snap.iops
            out[keys.wait_ms] = snap.mean_wait_ms
            out[keys.inflight] = float(snap.inflight)
            out[keys.ops] = float(snap.ops)
            out[keys.bytes] = float(snap.bytes)
            out[keys.wait_p50_ms] = snap.wait_p50_ms
            out[keys.wait_p95_ms] = snap.wait_p95_ms
            out[keys.wait_p99_ms] = snap.wait_p99_ms
            if snap.extras:
                _extras_to_samples(out, f"{stage}.{name}.", snap.extras)
            tot_ops += snap.ops
            tot_bytes += snap.bytes
            tot_tput += snap.throughput
            tot_iops += snap.iops
            tot_wait += snap.wait_seconds
            tot_inflight += snap.inflight
            if snap.wait_p50_ms > max_p50:
                max_p50 = snap.wait_p50_ms
            if snap.wait_p95_ms > max_p95:
                max_p95 = snap.wait_p95_ms
            if snap.wait_p99_ms > max_p99:
                max_p99 = snap.wait_p99_ms
        keys = cache.get((stage, None))
        if keys is None:
            keys = cache[(stage, None)] = _StatKeys(f"{stage}.")
        out[keys.throughput] = tot_tput
        out[keys.iops] = tot_iops
        out[keys.wait_ms] = (tot_wait / tot_ops) * 1e3 if tot_ops else 0.0
        out[keys.inflight] = float(tot_inflight)
        out[keys.ops] = float(tot_ops)
        out[keys.bytes] = float(tot_bytes)
        out[keys.wait_p50_ms] = max_p50
        out[keys.wait_p95_ms] = max_p95
        out[keys.wait_p99_ms] = max_p99
    return out


def _export_descriptor(entry: Tuple[str, Optional[str]], fld: str):
    stage, channel = entry
    if stage == FLEET_STAGE:
        # fleet views export under their own family, labeled by flow (a
        # global flow's channel name IS the flow across the fleet); the
        # whole-fleet aggregate row gets the reserved "_total" label
        return f"paio_fleet_{fld}", {"flow": channel if channel is not None else "_total"}
    if channel is None:
        return f"paio_stage_{fld}", {"stage": stage}
    return f"paio_channel_{fld}", {"stage": stage, "channel": channel}


class PolicyRuntime:
    """Installed policies + the trigger engine, one per control plane.

    Publishes into the **process-wide** registry by default
    (:func:`repro.telemetry.get_registry`), so one exporter endpoint covers
    every control plane and serve engine in the process; pass an explicit
    ``registry`` for isolation.
    """

    def __init__(self, registry: Optional[MetricRegistry] = None, clock=None) -> None:
        self.registry = registry if registry is not None else get_registry()
        #: the control plane forwards its clock so every time domain agrees:
        #: observe() ticks, cooldown stamps and restore_fired() all use the
        #: same (possibly virtual) clock — mixing domains would pin cooldowns
        self.trigger_engine = TriggerEngine(clock=clock)
        self._policies: Dict[str, CompiledPolicy] = {}
        self._versions: Dict[str, int] = {}
        self._version_counter = 0  #: bumps on every install/replace
        self._stats_keys: set = set()  # gauges owned by the last stats tick
        self._trigger_keys: set = set()  # trigger-state gauges we own
        self._hist_keys: set = set()  # cumulative wait histograms we own
        #: reused per-tick sample buffer + key-string cache (alloc churn fix)
        self._samples_buf: Dict[str, float] = {}
        self._key_cache: Dict[Tuple[str, Optional[str]], _StatKeys] = {}
        #: (stage, channel) entries whose export descriptors are registered
        self._described_entries: set = set()
        #: filter-plane extras gauge keys already described (paio_filter_*)
        self._described_extras: set = set()
        #: cumulative filter counter keys we own (paio_filter_*_total)
        self._filter_counter_keys: set = set()
        self._lock = threading.Lock()

    # -- lifecycle ---------------------------------------------------------
    def _publish_version(self, name: str, version: int) -> None:
        key = f"policy.{name}.version"
        self.registry.set_gauge(key, float(version))
        self.registry.describe(key, "paio_policy_version", {"policy": name})
        # derived from the registry itself (count of version gauges), so
        # multiple control planes sharing the process-wide registry cannot
        # clobber each other with per-plane counts. (Re)registered on every
        # install — two O(1) dict stores — so another runtime's close()
        # dropping the shared source can never leave it missing for good
        registry = self.registry
        registry.register(
            "policies.installed",
            lambda: float(registry.gauge_count("policy.", ".version")),
        )
        registry.describe("policies.installed", "paio_policies_installed")

    def install(self, compiled: CompiledPolicy) -> int:
        """Register ``compiled``; returns its (runtime-monotonic) version."""
        with self._lock:
            if compiled.name in self._policies:
                raise ValueError(f"policy {compiled.name!r} already installed")
            self._policies[compiled.name] = compiled
            self._version_counter += 1
            version = self._versions[compiled.name] = self._version_counter
            self._publish_version(compiled.name, version)
        for trigger in compiled.triggers:
            self.trigger_engine.add(trigger)
        self._preregister(compiled)
        return version

    def _preregister(self, compiled: CompiledPolicy) -> None:
        """Publish the policy's trigger-state gauges and (for global flows)
        its ``paio_fleet_*`` families at **zero** on install, so dashboards
        and the CI scrape see every family the policy can move before the
        first collect tick or firing (the ``paio_rpc_retries_total``
        convention from the transport layer). Keys that already carry a live
        value (atomic replace, overlapping policies) are described but not
        zeroed."""
        from repro.telemetry.histogram import NBUCKETS

        existing = set(self.registry.names())
        trigger_keys: List[str] = []
        for t in compiled.triggers:
            key = f"trigger.{t.qualified_name}.fired"
            pol, _, trig = t.qualified_name.partition("/")
            self.registry.describe(key, "paio_trigger_fired", {"policy": pol, "trigger": trig})
            if key not in existing:
                self.registry.set_gauge(key, 0.0)
            trigger_keys.append(key)
        fleet_entries: List[Tuple[str, Optional[str]]] = [
            (FLEET_STAGE, ch)
            for ch in sorted({f.channel_name() for f in compiled.policy.flows if f.is_global()})
        ]
        if fleet_entries:
            fleet_entries.append((FLEET_STAGE, None))
        stats_keys: List[str] = []
        hist_keys: List[str] = []
        for entry in fleet_entries:
            _, ch = entry
            prefix = f"{FLEET_STAGE}.{ch}." if ch is not None else f"{FLEET_STAGE}."
            for fld in CHANNEL_FIELDS:
                key = prefix + fld
                self.registry.describe(key, *_export_descriptor(entry, fld))
                if key not in existing:
                    self.registry.set_gauge(key, 0.0)
                stats_keys.append(key)
            if ch is not None:
                hkey = prefix + "wait_hist_ms"
                self.registry.describe(hkey, *_export_descriptor(entry, "wait_hist_ms"))
                self.registry.hist_add(hkey, (0,) * NBUCKETS)  # create at zero
                hist_keys.append(hkey)
        with self._lock:
            self._trigger_keys.update(trigger_keys)
            self._hist_keys.update(hist_keys)
        self._stats_keys |= set(stats_keys)

    def replace(self, compiled: CompiledPolicy) -> Tuple[CompiledPolicy, List[Any], int]:
        """Swap the stored policy named ``compiled.name`` in one step — the
        runtime never passes through a no-policy state. Old triggers leave
        the engine, new triggers enter armed with empty windows, and the
        version bumps. The control plane calls this only after the new
        version's rules are fully applied (it reads fired state up front via
        ``trigger_engine.fired_for``), so a failed replace never touches the
        runtime. Returns ``(old, fired_old_triggers, version)``.
        """
        with self._lock:
            old = self._policies.get(compiled.name)
            if old is None:
                raise KeyError(f"policy {compiled.name!r} is not installed")
            self._policies[compiled.name] = compiled
            self._version_counter += 1
            version = self._versions[compiled.name] = self._version_counter
            self._publish_version(compiled.name, version)
        fired = self.trigger_engine.remove_policy(compiled.name)
        # old triggers' state gauges go now (a renamed/dropped trigger must
        # not export paio_trigger_fired forever on a synchronous plane); the
        # new version's gauges publish on the next collect tick
        self._prune_trigger_gauges(compiled.name)
        for trigger in compiled.triggers:
            self.trigger_engine.add(trigger)
        self._preregister(compiled)
        return old, fired, version

    def _prune_trigger_gauges(self, policy_name: str) -> None:
        prefix = f"trigger.{policy_name}/"
        with self._lock:  # _trigger_keys is shared with the loop thread
            pruned = {k for k in self._trigger_keys if k.startswith(prefix)}
            self._trigger_keys -= pruned
        for key in pruned:
            self.registry.unregister(key)

    def remove(self, name: str):
        """Uninstall ``name``; returns ``(compiled, fired)`` where ``fired``
        are the triggers that were FIRED at removal (popped atomically from
        the engine, so the control loop cannot release them concurrently) —
        callers apply their release rules so fired enforcement state does not
        outlive the policy."""
        with self._lock:
            compiled = self._policies.pop(name, None)
            if compiled is not None:
                self._versions.pop(name, None)
                # the policies.installed source derives its count from the
                # remaining policy.*.version gauges — nothing else to update
                self.registry.unregister(f"policy.{name}.version")
        if compiled is None:
            raise KeyError(f"policy {name!r} is not installed")
        fired = self.trigger_engine.remove_policy(name)
        # drop the removed policy's trigger-state gauges NOW — a plane driven
        # synchronously (or with its loop stopped) would otherwise export
        # paio_trigger_fired 1 forever for a policy that no longer exists
        self._prune_trigger_gauges(name)
        return compiled, fired

    def get(self, name: str) -> Optional[CompiledPolicy]:
        with self._lock:
            return self._policies.get(name)

    def installed(self) -> List[CompiledPolicy]:
        """Snapshot of the installed compiled policies — the authoritative
        "what should exist on the stages" set the control plane reconciles
        deferred-rule replay against at stage recovery."""
        with self._lock:
            return list(self._policies.values())

    def list(self) -> List[Dict[str, Any]]:
        with self._lock:
            policies = list(self._policies.values())
            versions = dict(self._versions)
        states = self.trigger_engine.states()
        out = []
        for cp in policies:
            summary = cp.summary()
            summary["version"] = versions.get(cp.name)
            summary["trigger_states"] = {
                t.qualified_name: states.get(t.qualified_name, "armed") for t in cp.triggers
            }
            summary["algorithms"] = type(cp.algorithm).__name__ if cp.algorithm else None
            out.append(summary)
        return out

    def algorithms(self) -> List[Any]:
        with self._lock:
            return [cp.algorithm for cp in self._policies.values() if cp.algorithm is not None]

    def pinned_targets(self) -> set:
        """Targets owned by currently-fired triggers — see
        :meth:`TriggerEngine.pinned_targets`."""
        return self.trigger_engine.pinned_targets()

    def close(self) -> None:
        """Release every registry name this runtime owns (stats gauges,
        trigger states, policy versions) — for planes publishing into the
        shared registry that are being torn down for good."""
        with self._lock:
            owned = (
                self._stats_keys | self._trigger_keys | self._hist_keys | self._filter_counter_keys
            )
            self._trigger_keys = set()
            self._hist_keys = set()
        self._stats_keys = set()
        self._filter_counter_keys = set()
        self._described_extras = set()
        for key in owned:
            self.registry.unregister(key)
        with self._lock:
            names = list(self._versions)
        for name in names:
            self.registry.unregister(f"policy.{name}.version")
        # the policies.installed source is shared infra across runtimes on
        # one registry: drop it only when no version gauges remain at all
        if not any(
            n.startswith("policy.") and n.endswith(".version") for n in self.registry.names()
        ):
            self.registry.unregister("policies.installed")

    def __len__(self) -> int:
        with self._lock:
            return len(self._policies)

    # -- feedback-loop tick ------------------------------------------------
    def publish_trigger_states(self) -> None:
        states = self.trigger_engine.states()
        with self._lock:  # _trigger_keys is shared with remove/replace paths
            prev = set(self._trigger_keys)
            keys = {f"trigger.{qualified}.fired" for qualified in states}
            self._trigger_keys = keys
        for qualified, state in states.items():
            policy, _, trigger = qualified.partition("/")
            key = f"trigger.{qualified}.fired"
            self.registry.set_gauge(key, 1.0 if state == "fired" else 0.0)
            if key not in prev:
                self.registry.describe(
                    key, "paio_trigger_fired", {"policy": policy, "trigger": trigger}
                )
        for stale in prev - keys:
            self.registry.unregister(stale)

    def on_collect(
        self, now: float, stats: Mapping[str, StageStats]
    ) -> List[TriggerEvent]:
        """One tick: push stats into the registry, sample, evaluate triggers.

        Stage gauges are replaced wholesale each tick: a channel that
        disappeared (policy teardown, stage removal) takes its gauges with it,
        so triggers see the metric as *absent* (state frozen) rather than as
        a stale constant. Returns the trigger transitions; the caller applies
        each event's ``rules`` (stage → wire rules) through its stage handles.

        Member snapshots are folded into the **fleet view** (pseudo-stage
        ``@fleet``) before publication: ``@fleet.<channel>.throughput`` is the
        sum over every member instance, ``@fleet.<channel>.wait_p99_ms`` comes
        from the exactly-merged wait histograms — the sample set cluster-scoped
        triggers evaluate against. Control algorithms never see the fold (it
        exists only in the metric plane).
        """
        all_stats: Mapping[str, StageStats] = (
            {**stats, FLEET_STAGE: fleet_view(stats)} if stats else stats
        )
        gauges = stats_to_samples(all_stats, out=self._samples_buf, key_cache=self._key_cache)
        keys = set(gauges)
        stale_keys = self._stats_keys - keys
        if stale_keys:
            for stale in stale_keys:
                self.registry.unregister(stale)
                self._described_extras.discard(stale)
            # evict key-string cache entries for vanished channels too, or a
            # long-lived plane churning per-tenant channels leaks one
            # _StatKeys per channel name ever seen
            live = {(stage, ch) for stage, st in all_stats.items() for ch in st.per_channel}
            live.update((stage, None) for stage in all_stats)
            for gone in [k for k in self._key_cache if k not in live]:
                del self._key_cache[gone]
                self._described_entries.discard(gone)
        # describe once per (stage, channel): the identity is known at key
        # creation, so this is O(new channels), not a scan over fresh keys
        for entry, sk in self._key_cache.items():
            if entry in self._described_entries:
                continue
            for fld in CHANNEL_FIELDS:
                self.registry.describe(getattr(sk, fld), *_export_descriptor(entry, fld))
            self._described_entries.add(entry)
        # extras gauges (filter plane) are not covered by the _StatKeys
        # descriptor pass: their keys have a dotted suffix
        # (<stage>.<channel>.cache.hit_rate → >= 3 dots), which no builtin
        # stage/channel gauge has, so the shape test is exact
        for key in keys:
            if key in self._described_extras or key.count(".") < 3:
                continue
            stage, ch, suffix = key.split(".", 2)
            self.registry.describe(
                key,
                f"paio_filter_{suffix.replace('.', '_')}",
                {"stage": stage, "channel": ch},
            )
            self._described_extras.add(key)
        self._stats_keys = keys
        self.registry.update_gauges(gauges)
        # window eviction deltas additionally feed a cumulative counter —
        # eviction *rate* is a gauge readers can miss between scrapes; the
        # monotone total is the honest Prometheus form
        for stage, st in all_stats.items():
            for ch, snap in st.per_channel.items():
                ev = snap.extras.get("cache.evictions") if snap.extras else None
                if not ev:
                    continue
                ckey = f"{stage}.{ch}.cache.evictions_total"
                if ckey not in self._filter_counter_keys:
                    self.registry.describe(
                        ckey, "paio_filter_cache_evictions_total", {"stage": stage, "channel": ch}
                    )
                    self._filter_counter_keys.add(ckey)
                self.registry.inc(ckey, ev)
        # cumulative wait histograms: each tick merges the window's bucket
        # deltas in (exact, associative), per channel and per fleet view —
        # the exporter renders them as native _bucket/_sum/_count families
        hist_keys: set = set()
        for stage, st in all_stats.items():
            for ch, snap in st.per_channel.items():
                if not snap.wait_hist:
                    continue  # old-wire peer without histograms
                key = f"{stage}.{ch}.wait_hist_ms"
                hist_keys.add(key)
                if key not in self._hist_keys:
                    self.registry.describe(key, *_export_descriptor((stage, ch), "wait_hist_ms"))
                self.registry.hist_add(key, snap.wait_hist, snap.wait_seconds * 1e3)
        with self._lock:
            stale_hists = self._hist_keys - hist_keys
            self._hist_keys = hist_keys
        for stale in stale_hists:
            self.registry.unregister(stale)
        samples = self.registry.sample()
        # trigger-state gauges are NOT published here — the control plane
        # calls publish_trigger_states() after it has applied the returned
        # events' rules, so a scraped "fired" always means the enforcement
        # actually landed (and the scraped reaction latency includes rule
        # application, not just predicate evaluation)
        return self.trigger_engine.observe(now, samples)


def missing_install_rules(
    installed: List[CompiledPolicy], stage_name: str, info: Mapping[str, Any]
) -> List[Any]:
    """Install rules to re-ship to a recovered stage, judged against its live
    ``stage_info()``.

    A recovered stage is not necessarily empty: a crash-restarted process may
    have restored its configuration from a :class:`~repro.core.snapshot.
    StageConfigJournal` before re-registering, and replaying every installed
    policy from zero would be pure waste (and, at fleet scale, a recovery
    stampede). Instead, each installed policy's install program for
    ``stage_name`` is checked against the entities the stage actually has:
    only policies with a **missing** channel or enforcement object get their
    program back — in full, because rule application is idempotent
    (create-if-present retunes, routes re-install over themselves) and routes
    are not individually introspectable from ``stage_info`` (the routing
    table exposes masks and entry counts, not matches), so a partial re-ship
    could not prove route coverage anyway.
    """
    from repro.core.channel import DEFAULT_OBJECT_ID

    from .compile import _install_key

    channels = info.get("channels") or {}
    out: List[Any] = []
    for compiled in installed:
        rules = compiled.install.get(stage_name) or []
        missing = False
        for rule in rules:
            key = _install_key(rule)
            if key is None:
                continue
            if key[0] == "chan" and key[1] not in channels:
                missing = True
                break
            if key[0] == "obj":
                chan = channels.get(key[1])
                oid = key[2] or DEFAULT_OBJECT_ID
                if chan is None or oid not in (chan.get("objects") or {}):
                    missing = True
                    break
            if key[0] == "filter":
                chan = channels.get(key[1])
                if chan is None or key[2] not in (chan.get("filters") or {}):
                    missing = True
                    break
        if missing:
            out.extend(rules)
    return out
