"""Declarative policy subsystem (Crystal-style control for the PAIO data plane).

Policies — not code — define what the storage data plane does: which flows
exist (differentiation), how they are provisioned (enforcement objects), what
closed-loop objective governs them (fair share / tail latency) and which
metrics-driven triggers adapt them at runtime. The pipeline:

    text DSL / JSON  ──parse──▶  Policy  ──compile──▶  wire rules + triggers
                                             │
                               ControlPlane.install_policy (local or UDS)

See :mod:`repro.policy.dsl` for the language, :mod:`repro.policy.compile`
for validation/lowering and :mod:`repro.policy.triggers` for the windowed
trigger engine.
"""
from .compile import (
    BUILTIN_METRICS,
    DEMOTE_FACTOR,
    CompiledPolicy,
    PolicyDelta,
    compile_policy,
    diff_policies,
    infos_without_policy,
)
from .dsl import (
    Action,
    Condition,
    Flow,
    Objective,
    ObjectSpec,
    Policy,
    PolicyError,
    TriggerSpec,
    load_policy,
    load_policy_file,
    parse_duration,
    parse_policy_text,
    parse_quantity,
    policy_from_dict,
    policy_to_dict,
)
from .engine import PolicyRuntime, stats_to_samples
from .triggers import (
    CompiledTrigger,
    SlidingWindow,
    TriggerEngine,
    TriggerEvent,
)

__all__ = [
    "BUILTIN_METRICS",
    "DEMOTE_FACTOR",
    "Action",
    "CompiledPolicy",
    "CompiledTrigger",
    "Condition",
    "Flow",
    "Objective",
    "ObjectSpec",
    "Policy",
    "PolicyDelta",
    "PolicyError",
    "PolicyRuntime",
    "SlidingWindow",
    "TriggerEngine",
    "TriggerEvent",
    "TriggerSpec",
    "compile_policy",
    "diff_policies",
    "infos_without_policy",
    "load_policy",
    "load_policy_file",
    "parse_duration",
    "parse_policy_text",
    "parse_quantity",
    "policy_from_dict",
    "policy_to_dict",
    "stats_to_samples",
]
