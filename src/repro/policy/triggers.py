"""Metrics-driven trigger engine (Crystal's dynamic-policy actor, PAIO-ified).

The control plane feeds every collect tick into the engine: stage statistics
become metric samples, samples accumulate in per-trigger sliding windows, and
windowed predicates (``agg(metric over window) op threshold``) decide when a
trigger *fires* (apply its actions' rules) or *releases* (apply the release
rules). Two mechanisms keep an oscillating metric from flapping rules on and
off every tick:

* **hysteresis** — a fired ``>`` trigger only resets once the aggregate drops
  below ``threshold - hysteresis`` (mirrored for ``<``), and
* **cooldown** — a minimum time between consecutive fires.

The engine is transport-agnostic: it evaluates pure state and returns the wire
rules to apply; the control plane ships them through whichever StageHandle
(local or UDS) hosts the target stage.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from repro.core.clock import Clock, DEFAULT_CLOCK
from repro.telemetry.metrics import quantile


# --------------------------------------------------------------------------- #
# sliding windows                                                              #
# --------------------------------------------------------------------------- #
class SlidingWindow:
    """Time-bounded sample window with the DSL's aggregations.

    Samples are (timestamp, value) pairs; aggregation prunes anything older
    than ``window`` seconds before computing. Percentiles use the
    nearest-rank method over the retained samples.
    """

    __slots__ = ("window", "_buf")

    def __init__(self, window: float) -> None:
        self.window = float(window)
        self._buf: Deque[Tuple[float, float]] = deque()

    def push(self, t: float, value: float) -> None:
        self._buf.append((t, float(value)))
        self._prune(t)

    def _prune(self, now: float) -> None:
        cutoff = now - self.window
        buf = self._buf
        while buf and buf[0][0] < cutoff:
            buf.popleft()

    def __len__(self) -> int:
        return len(self._buf)

    def aggregate(self, agg: str) -> Optional[float]:
        """None when the window holds no samples (predicates stay idle)."""
        buf = self._buf
        if not buf:
            return None
        if agg == "last":
            return buf[-1][1]
        values = [v for _, v in buf]
        if agg == "mean":
            return sum(values) / len(values)
        if agg == "min":
            return min(values)
        if agg == "max":
            return max(values)
        if agg == "rate":
            # Δvalue/Δt over the window (for monotonically-growing counters)
            if len(buf) < 2:
                return 0.0
            (t0, v0), (t1, v1) = buf[0], buf[-1]
            return (v1 - v0) / max(t1 - t0, 1e-9)
        if agg in ("p50", "p95", "p99"):
            values.sort()
            return quantile(values, {"p50": 0.5, "p95": 0.95, "p99": 0.99}[agg])
        raise ValueError(f"unknown aggregation {agg!r}")


def compare(op: str, left: float, right: float) -> bool:
    """DSL comparison semantics, exactly as the operators read: ``>`` is
    *strictly* greater — an aggregate landing exactly on the threshold does
    NOT fire a ``>`` trigger (use ``>=`` for fire-at-threshold), mirrored
    for ``<`` vs ``<=``."""
    if op == ">":
        return left > right
    if op == ">=":
        return left >= right
    if op == "<":
        return left < right
    if op == "<=":
        return left <= right
    if op == "==":
        return left == right
    if op == "!=":
        return left != right
    raise ValueError(f"unknown comparison {op!r}")


def release_condition(op: str, agg_value: float, threshold: float, hysteresis: float) -> bool:
    """Has a fired trigger crossed back out of its (hysteresis-widened) band?

    For ``>``/``>=`` the release point is ``threshold - hysteresis``; for
    ``<``/``<=`` it is ``threshold + hysteresis``; equality ops release when
    the predicate is simply false.
    """
    if op in (">", ">="):
        return agg_value <= threshold - hysteresis
    if op in ("<", "<="):
        return agg_value >= threshold + hysteresis
    return not compare(op, agg_value, threshold)


# --------------------------------------------------------------------------- #
# compiled triggers + engine                                                   #
# --------------------------------------------------------------------------- #
@dataclass
class CompiledTrigger:
    """A trigger lowered to wire rules, ready for evaluation.

    ``fire_rules`` / ``release_rules`` map stage name → list of wire rule
    objects (Housekeeping/Differentiation/Enforcement) to submit on the
    transition.
    """

    policy: str
    name: str
    metric_key: str
    agg: str
    op: str
    value: float
    window: float
    hysteresis: float
    cooldown: float
    fire_rules: Dict[str, List[Any]]
    release_rules: Dict[str, List[Any]]

    @property
    def qualified_name(self) -> str:
        return f"{self.policy}/{self.name}"


@dataclass
class TriggerEvent:
    """One trigger transition the control plane must enact."""

    trigger: CompiledTrigger
    kind: str  # "fire" | "release"
    at: float
    agg_value: float
    rules: Dict[str, List[Any]] = field(default_factory=dict)


class _TriggerRuntime:
    __slots__ = ("spec", "window", "fired", "last_fire")

    def __init__(self, spec: CompiledTrigger) -> None:
        self.spec = spec
        self.window = SlidingWindow(spec.window)
        self.fired = False
        self.last_fire = -float("inf")


class TriggerEngine:
    """Evaluates all installed triggers against incoming metric samples.

    All interval math (window eviction, cooldown, hysteresis timing) runs on
    the ``now`` values fed to :meth:`observe` — the control plane passes its
    monotonic clock's time, so a wall-clock step (NTP, suspend/resume) can
    neither evict a live window nor pin a cooldown. When ``observe`` is
    called without ``now``, the engine's own injectable ``clock`` supplies
    it (tests inject a fake clock here to prove clock-jump immunity).
    """

    def __init__(self, clock: Optional[Clock] = None) -> None:
        self._triggers: Dict[str, _TriggerRuntime] = {}
        self._clock = clock if clock is not None else DEFAULT_CLOCK
        self._lock = threading.Lock()

    # -- lifecycle ---------------------------------------------------------
    def add(self, trigger: CompiledTrigger) -> None:
        with self._lock:
            self._triggers[trigger.qualified_name] = _TriggerRuntime(trigger)

    def remove_policy(self, policy: str) -> List[CompiledTrigger]:
        """Drop every trigger of ``policy``; returns the ones that were FIRED
        (callers may want to apply their release rules on uninstall)."""
        dropped: List[CompiledTrigger] = []
        with self._lock:
            for key in [k for k, rt in self._triggers.items() if rt.spec.policy == policy]:
                rt = self._triggers.pop(key)
                if rt.fired:
                    dropped.append(rt.spec)
        return dropped

    def triggers(self) -> List[CompiledTrigger]:
        with self._lock:
            return [rt.spec for rt in self._triggers.values()]

    def fired_for(self, policy: str) -> List[CompiledTrigger]:
        """Read-only snapshot of ``policy``'s currently-FIRED triggers
        (the atomic-replace path releases their state before re-provisioning
        without yet removing them from the engine)."""
        with self._lock:
            return [
                rt.spec
                for rt in self._triggers.values()
                if rt.fired and rt.spec.policy == policy
            ]

    def states(self) -> Dict[str, str]:
        with self._lock:
            return {
                k: ("fired" if rt.fired else "armed") for k, rt in self._triggers.items()
            }

    def metric_keys(self) -> List[str]:
        with self._lock:
            return sorted({rt.spec.metric_key for rt in self._triggers.values()})

    def pinned_targets(self) -> set:
        """(stage, channel, object_id) triples currently held by FIRED triggers.

        While a trigger is fired it owns the objects its fire rules configure:
        the control plane suppresses algorithm enforcement rules for pinned
        targets so a closed-loop objective cannot immediately overwrite a
        protective action (e.g. re-raising a demoted flow's rate every tick).
        """
        pinned = set()
        with self._lock:
            runtimes = [rt for rt in self._triggers.values() if rt.fired]
        for rt in runtimes:
            for stage, rules in rt.spec.fire_rules.items():
                for rule in rules:
                    oid = getattr(rule, "object_id", None)
                    if oid is not None and hasattr(rule, "state"):
                        pinned.add((stage, rule.channel, oid))
        return pinned

    # -- evaluation --------------------------------------------------------
    def observe(self, now: Optional[float], samples: Dict[str, float]) -> List[TriggerEvent]:
        """Feed one tick of metric samples; returns the transitions to enact.

        ``now`` must come from a monotonic time source (pass None to use the
        engine's clock). A trigger whose metric is absent from ``samples``
        keeps its window (and state) untouched — a temporarily missing metric
        must not release a protective rule.
        """
        if now is None:
            now = self._clock.now()
        events: List[TriggerEvent] = []
        with self._lock:
            runtimes = list(self._triggers.values())
        for rt in runtimes:
            spec = rt.spec
            value = samples.get(spec.metric_key)
            if value is None:
                continue
            rt.window.push(now, value)
            agg = rt.window.aggregate(spec.agg)
            if agg is None:
                continue
            if not rt.fired:
                if compare(spec.op, agg, spec.value) and (now - rt.last_fire) >= spec.cooldown:
                    rt.fired = True
                    rt.last_fire = now
                    events.append(
                        TriggerEvent(spec, "fire", now, agg, rules=spec.fire_rules)
                    )
            else:
                if release_condition(spec.op, agg, spec.value, spec.hysteresis):
                    rt.fired = False
                    events.append(
                        TriggerEvent(spec, "release", now, agg, rules=spec.release_rules)
                    )
        return events
