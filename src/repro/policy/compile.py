"""Policy compiler: lower a typed :class:`Policy` to wire-serializable rules.

Compilation is where a policy meets reality: every flow, action, trigger and
objective is resolved against the registered stages' ``stage_info()`` so that
unknown stages, channels, enforcement objects, classifiers and metrics fail
**at compile time** — never in the control loop. The output is a
:class:`CompiledPolicy`:

* ``install``  — ordered housekeeping + differentiation rules per stage,
* ``teardown`` — the inverse rules (remove routes/objects/channels we made),
* ``triggers`` — :class:`CompiledTrigger` entries for the trigger engine with
  their fire/release rules already lowered,
* ``algorithm`` — a ControlAlgorithm when the policy declares an objective
  (fair share / tail latency), built through the algorithms' ``from_policy``
  constructors so hand-coded and policy-driven control are the same code.

Everything in ``install``/``teardown``/trigger rules is a plain rule dataclass
(:mod:`repro.core.rules`), so a compiled policy applies identically through a
local handle or the UNIX-socket transport.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core.objects import OBJECT_KINDS
from repro.core.rules import DifferentiationRule, EnforcementRule, HousekeepingRule
from repro.core.shard import shard_stage_names

from repro.filters.registry import FILTER_REGISTRY, FilterError
from repro.filters.spec import FilterSpec

from .dsl import (
    Action,
    Condition,
    FilterDecl,
    Flow,
    ObjectSpec,
    Policy,
    PolicyError,
    TriggerSpec,
    parse_quantity,
)
from .triggers import CompiledTrigger

#: builtin per-channel metric fields derivable from StatsSnapshot collects
BUILTIN_METRICS = (
    "throughput", "iops", "wait_ms", "inflight", "ops", "bytes",
    "wait_p50_ms", "wait_p95_ms", "wait_p99_ms",
)
#: accepted aliases for builtin metric names
METRIC_ALIASES = {
    "bandwidth": "throughput",
    "latency_ms": "wait_ms",
    **{m: m for m in BUILTIN_METRICS},
}

#: pseudo-stage the policy runtime publishes fleet-folded views under; the
#: leading "@" keeps it out of the real stage namespace (stage names come
#: from Stage(name=...), which has no reason to start with "@")
FLEET_STAGE = "@fleet"

#: percentile agg → the windowed merged-histogram percentile gauge it
#: resolves to on fleet scope
_FLEET_PCTL_FIELDS = {"p50": "wait_p50_ms", "p95": "wait_p95_ms", "p99": "wait_p99_ms"}

#: a demoted flow's DRL runs at provisioned_rate / DEMOTE_FACTOR (floor 1.0)
DEMOTE_FACTOR = 10.0


#: placeholder member stage for OFFLINE compiles of ``scope: global`` flows
#: (no registered stages to enumerate) — validation-only output, never
#: installed: ControlPlane.install_policy always compiles against live infos
UNRESOLVED_STAGE = "*"


@dataclass
class _FlowBinding:
    """A flow resolved to its physical location(s) + DRL provisioning.

    ``member_stages`` is the list of stages the flow is instantiated on: one
    entry for a stage-scoped flow, every registered stage for ``scope:
    global`` (same channel name + objects on each). ``stage`` stays the
    primary (first) member for error messages and single-member paths.
    """

    flow: Flow
    stage: str
    channel: str
    member_stages: List[str] = field(default_factory=list)
    drl_object_id: Optional[str] = None
    provisioned_rate: Optional[float] = None
    demote_rate: Optional[float] = None


@dataclass
class CompiledPolicy:
    policy: Policy
    install: Dict[str, List[Any]] = field(default_factory=dict)
    teardown: Dict[str, List[Any]] = field(default_factory=dict)
    triggers: List[CompiledTrigger] = field(default_factory=list)
    algorithm: Optional[Any] = None

    @property
    def name(self) -> str:
        return self.policy.name

    def stages(self) -> List[str]:
        out = set(self.install) | set(self.teardown)
        for t in self.triggers:
            out.update(t.fire_rules)
            out.update(t.release_rules)
        return sorted(out)

    def summary(self) -> Dict[str, Any]:
        return {
            "policy": self.policy.name,
            "stages": self.stages(),
            "flows": [f.name for f in self.policy.flows],
            "triggers": [t.qualified_name for t in self.triggers],
            "objective": self.policy.objective.kind if self.policy.objective else None,
        }


def compile_policy(
    policy: Policy,
    infos: Optional[Mapping[str, Mapping[str, Any]]] = None,
    default_stage: Optional[str] = None,
) -> CompiledPolicy:
    """Lower ``policy`` to rules, validating against ``infos`` when given.

    ``infos`` maps stage name → ``stage_info()`` dict (from either transport).
    When ``infos`` is None the compile is *offline*: structure is checked but
    existence of stages/channels is deferred to install time.
    """
    cp = CompiledPolicy(policy=policy)
    bindings = _bind_flows(policy, infos, default_stage)

    for b in bindings.values():
        _lower_flow(cp, b, infos)

    for spec in policy.triggers:
        cp.triggers.append(_lower_trigger(policy, spec, bindings, infos, default_stage))

    if policy.objective is not None:
        cp.algorithm = _lower_objective(policy, bindings)
    return cp


# --------------------------------------------------------------------------- #
# flows                                                                        #
# --------------------------------------------------------------------------- #
def _resolve_stage(
    policy: Policy,
    flow_stage: Optional[str],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
    what: str,
) -> str:
    stage = flow_stage or policy.stage or default_stage
    if stage is None:
        if infos is not None and len(infos) == 1:
            return next(iter(infos))
        raise PolicyError(
            f"{what}: no stage named (set the policy 'stage', the flow 'stage', "
            "or register exactly one stage)"
        )
    if infos is not None and stage not in infos:
        raise PolicyError(f"{what}: unknown stage {stage!r} (registered: {sorted(infos)})")
    return stage


def _bind_flows(
    policy: Policy,
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
) -> Dict[str, _FlowBinding]:
    bindings: Dict[str, _FlowBinding] = {}
    for flow in policy.flows:
        if flow.is_global():
            if policy.shards is not None:
                # sharded logical stage: the global flow spans exactly the
                # policy's shard stages (<stage>/0 … <stage>/N-1) — member
                # names are deterministic, so even an offline compile binds
                # real members; an online compile additionally proves every
                # shard is registered
                members = shard_stage_names(policy.stage, policy.shards)
                if infos is not None:
                    missing = [m for m in members if m not in infos]
                    if missing:
                        raise PolicyError(
                            f"flow {flow.name!r}: policy declares shards={policy.shards} "
                            f"but shard stages {missing} are not registered "
                            f"(registered: {sorted(infos)})"
                        )
            elif infos is None:
                # offline compile: structure-check against a placeholder
                # member; existence resolves when installed against live infos
                members = [UNRESOLVED_STAGE]
            else:
                members = sorted(infos)
                if not members:
                    raise PolicyError(
                        f"flow {flow.name!r}: 'scope: global' needs at least one "
                        "registered stage"
                    )
        else:
            members = [
                _resolve_stage(policy, flow.stage, infos, default_stage, f"flow {flow.name!r}")
            ]
        b = _FlowBinding(
            flow=flow, stage=members[0], channel=flow.channel_name(), member_stages=members
        )
        for obj in flow.objects:
            if obj.kind not in OBJECT_KINDS:
                raise PolicyError(
                    f"flow {flow.name!r}: unknown object kind {obj.kind!r} "
                    f"(known: {sorted(OBJECT_KINDS)})"
                )
            _dry_construct(flow, obj)
            if obj.kind == "drl":
                params = obj.params_dict()
                if "rate" not in params:
                    raise PolicyError(f"flow {flow.name!r}: drl object needs a 'rate'")
                b.drl_object_id = obj.object_id
                b.provisioned_rate = parse_quantity(params["rate"])
                b.demote_rate = parse_quantity(
                    params.get("demote_rate") or max(b.provisioned_rate / DEMOTE_FACTOR, 1.0)
                )
        bindings[flow.name] = b
    return bindings


def _dry_construct(flow: Flow, obj: ObjectSpec) -> None:
    """Validate enforcement-object params by constructing a throwaway
    instance, so a typo'd or bad-valued param fails at compile time instead
    of mid-install (which would leave partial stage state). ImportError is
    deliberately not treated as a compile error: an object whose optional
    dependency is missing fails identically at install, and compiling a
    policy should not require the dependency."""
    params = obj.params_dict()
    params.pop("demote_rate", None)
    try:
        OBJECT_KINDS[obj.kind](**params)
    except (TypeError, ValueError) as exc:
        raise PolicyError(
            f"flow {flow.name!r}: bad params for {obj.kind!r} object "
            f"{obj.object_id!r}: {exc}"
        ) from None
    except ImportError:
        pass


def _lower_flow(
    cp: CompiledPolicy, b: _FlowBinding, infos: Optional[Mapping[str, Any]]
) -> None:
    for stage in b.member_stages:
        _lower_flow_on(cp, b, stage, infos)


def _lower_flow_on(
    cp: CompiledPolicy, b: _FlowBinding, stage: str, infos: Optional[Mapping[str, Any]]
) -> None:
    install = cp.install.setdefault(stage, [])
    teardown: List[Any] = []
    existing = (infos or {}).get(stage, {}).get("channels", {}) if infos is not None else {}
    channel_exists = b.channel in existing

    if not channel_exists:
        install.append(HousekeepingRule(op="create_channel", channel=b.channel))
    for obj in b.flow.objects:
        params = obj.params_dict()
        params.pop("demote_rate", None)  # compile-time knob, not an obj_init param
        if channel_exists:
            have = existing.get(b.channel, {}).get("objects", {})
            prior = have.get(obj.object_id)
            if prior is not None and prior.get("kind") not in (None, "noop"):
                raise PolicyError(
                    f"flow {b.flow.name!r}: object {obj.object_id!r} already exists on "
                    f"channel {b.channel!r} (kind {prior.get('kind')!r}); refusing to replace"
                )
        install.append(
            HousekeepingRule(
                op="create_object",
                channel=b.channel,
                object_id=obj.object_id,
                object_kind=obj.kind,
                params=params,
            )
        )
        if channel_exists:  # channel outlives the policy: remove objects one by one
            teardown.append(
                HousekeepingRule(op="remove_object", channel=b.channel, object_id=obj.object_id)
            )
    for flt in b.flow.filters:
        spec = _pin_filter(b, stage, flt, infos)
        if channel_exists:
            have = existing.get(b.channel, {}).get("filters", {})
            prior = have.get(spec.filter_id)
            if prior is not None:
                raise PolicyError(
                    f"flow {b.flow.name!r}: filter slot {spec.filter_id!r} already exists "
                    f"on channel {b.channel!r} ({prior.get('name')!r} "
                    f"v{prior.get('version')}); refusing to replace"
                )
            # channel outlives the policy: uninstall filters one by one
            teardown.append(spec.removal_rule())
        install.append(spec.to_rule())
    match = b.flow.match_dict()
    install.append(DifferentiationRule(channel=b.channel, match=match))
    teardown.append(
        HousekeepingRule(op="remove_route", channel=b.channel, params={"match": match})
    )
    if not channel_exists:
        teardown.append(HousekeepingRule(op="remove_channel", channel=b.channel))
    cp.teardown.setdefault(stage, []).extend(teardown)


def _pin_filter(
    b: _FlowBinding, stage: str, flt: FilterDecl, infos: Optional[Mapping[str, Any]]
) -> FilterSpec:
    """Validate one filter declaration against the target stage's advertised
    filter registry (``stage_info()["filters"]``) and pin ``version: 0`` to
    the concrete latest, so the installed configuration is reproducible.
    Offline compiles (and stages that predate the filter plane and advertise
    nothing) validate against the local registry — the same code both sides
    run — so typos still fail at compile time."""
    what = f"flow {b.flow.name!r}"
    advert = None
    if infos is not None:
        advert = (infos.get(stage) or {}).get("filters")
    if advert is None:
        advert = FILTER_REGISTRY.advertise()
    entry = advert.get(flt.name)
    if entry is None:
        raise PolicyError(
            f"{what}: unknown filter {flt.name!r} on stage {stage!r} "
            f"(advertised: {sorted(advert)})"
        )
    version = flt.version or int(entry.get("latest", 0))
    if version not in entry.get("versions", ()):
        raise PolicyError(
            f"{what}: filter {flt.name!r} has no version {version} on stage {stage!r} "
            f"(advertised: {sorted(entry.get('versions', ()))})"
        )
    params = flt.params_dict()
    if version == entry.get("latest"):
        known = set(entry.get("params", ()))
        unknown = sorted(set(params) - known)
        if unknown:
            raise PolicyError(
                f"{what}: filter {flt.name!r} does not accept param(s) {unknown} "
                f"(accepted: {sorted(known)})"
            )
    # dry-construct when the local registry has the pinned version, so bad
    # param *values* also fail at compile time instead of mid-install
    try:
        FILTER_REGISTRY.lookup(flt.name, version)
    except FilterError:
        pass
    else:
        try:
            FILTER_REGISTRY.create(flt.name, version, params)
        except FilterError as exc:
            raise PolicyError(f"{what}: bad filter {flt.name!r} params: {exc}") from None
    return FilterSpec(
        name=flt.name,
        version=version,
        channel=b.channel,
        filter_id=flt.slot(),
        params=params,
    )


# --------------------------------------------------------------------------- #
# actions                                                                      #
# --------------------------------------------------------------------------- #
def _resolve_action_flow(
    policy: Policy, bindings: Dict[str, _FlowBinding], ref: Optional[str], what: str
) -> _FlowBinding:
    if ref is None:
        raise PolicyError(f"{what}: action needs a target flow")
    if ref in bindings:
        return bindings[ref]
    if "=" in ref:  # "tenant=analytics" → the flow with exactly that match
        from .dsl import _canon_match  # noqa: PLC0415 — shared canonicalization

        key, _, val = ref.partition("=")
        want = _canon_match({key: val})
        for b in bindings.values():
            if b.flow.match == want:
                return b
    raise PolicyError(
        f"{what}: unknown flow {ref!r} (declared: {sorted(bindings)})"
    )


def _lower_action(
    policy: Policy,
    bindings: Dict[str, _FlowBinding],
    action: Action,
    what: str,
    infos: Optional[Mapping[str, Any]],
) -> List[Tuple[str, List[Any]]]:
    """Returns ``[(stage, rules), ...]`` for one action — one entry per
    member stage of the target flow, so an action against a ``scope:
    global`` flow lands on every instance."""
    if action.op == "set":
        b = _resolve_action_flow(policy, bindings, action.flow, what)
        state = action.state_dict()
        if not state:
            raise PolicyError(f"{what}: 'set' action with empty state")
        return [
            (
                stage,
                [
                    EnforcementRule(
                        channel=b.channel,
                        object_id=_check_object(infos, b, stage, action.object_id, what),
                        state=state,
                    )
                ],
            )
            for stage in b.member_stages
        ]
    if action.op in ("demote", "promote"):
        b = _resolve_action_flow(policy, bindings, action.flow, what)
        if b.drl_object_id is None:
            raise PolicyError(
                f"{what}: {action.op} targets flow {b.flow.name!r} which provisions no DRL "
                "(add 'limit bandwidth …' to the flow)"
            )
        rate = b.demote_rate if action.op == "demote" else b.provisioned_rate
        return [
            (
                stage,
                [EnforcementRule(channel=b.channel, object_id=b.drl_object_id, state={"rate": rate})],
            )
            for stage in b.member_stages
        ]
    raise PolicyError(f"{what}: unknown action op {action.op!r}")


def _check_object(
    infos: Optional[Mapping[str, Any]], b: _FlowBinding, stage: str, object_id: str, what: str
) -> str:
    """An action's target object must be provisioned by the policy or already
    live on the stage (when stage info is available to check). Returns the
    object id for inline use."""
    if any(o.object_id == object_id for o in b.flow.objects):
        return object_id
    if infos is None:
        return object_id
    have = infos.get(stage, {}).get("channels", {}).get(b.channel, {}).get("objects", {})
    if object_id not in have:
        raise PolicyError(
            f"{what}: object {object_id!r} not provisioned on flow {b.flow.name!r} "
            f"and not present on stage {stage!r} channel {b.channel!r}"
        )
    return object_id


# --------------------------------------------------------------------------- #
# triggers                                                                     #
# --------------------------------------------------------------------------- #
def _fleet_key(canon: str, channel: Optional[str], cond: Condition) -> Tuple[str, Optional[str]]:
    """Registry key (+ optional agg override) for a fleet-scoped condition.

    Percentile aggs over ``wait_ms`` resolve to the merged-histogram windowed
    percentile gauges (``@fleet.<ch>.wait_p99_ms`` — exact over the union of
    every member's per-op observations), with the agg overridden to ``max``:
    the trigger then watches the worst windowed tail inside its own sliding
    window, which is the conservative reading of "p99 over the window" when
    the per-tick value is already a percentile."""
    if canon == "wait_ms" and cond.agg in _FLEET_PCTL_FIELDS:
        fld = _FLEET_PCTL_FIELDS[cond.agg]
        key = f"{FLEET_STAGE}.{channel}.{fld}" if channel else f"{FLEET_STAGE}.{fld}"
        return key, "max"
    key = f"{FLEET_STAGE}.{channel}.{canon}" if channel else f"{FLEET_STAGE}.{canon}"
    return key, None


def _resolve_metric_key(
    policy: Policy,
    cond: Condition,
    bindings: Dict[str, _FlowBinding],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
    what: str,
) -> Tuple[str, Optional[str]]:
    """Resolve a condition to ``(registry key, agg override)``.

    Builtin metrics on ``scope: global`` flows resolve to the fleet metric
    plane (``@fleet.<channel>.<metric>``): the policy runtime folds member
    snapshots into one honest aggregate per collect tick — Σ throughput,
    merged-histogram percentiles — so the PR-4 "ambiguous across member
    stages" rejection no longer applies. ``@fleet.<flow>`` / ``@fleet``
    qualifiers force fleet scope explicitly (the latter aggregates over
    every channel of the control plane's fleet view).
    """
    canon = METRIC_ALIASES.get(cond.metric)
    if "." in cond.metric and canon is None:
        if cond.flow is None or cond.metric.startswith(f"{FLEET_STAGE}."):
            # fully-qualified registry key — pluggable, pass through
            return cond.metric, None
        # dotted metric scoped to a flow (the filter-plane extras channel:
        # ``cache.hit_rate@cold``) — qualify with the flow's stage + channel
        # exactly like a builtin, fleet-folded for global flows
        b = _resolve_action_flow(policy, bindings, cond.flow, what)
        if b.flow.is_global():
            return f"{FLEET_STAGE}.{b.channel}.{cond.metric}", None
        return f"{b.stage}.{b.channel}.{cond.metric}", None
    if canon is None:
        raise PolicyError(
            f"{what}: unknown metric {cond.metric!r} "
            f"(builtins: {sorted(set(METRIC_ALIASES))}; registry metrics use dotted names)"
        )
    flow_ref = cond.flow
    fleet = False
    if flow_ref == "fleet":
        fleet = True
        flow_ref = None
    elif flow_ref is not None and flow_ref.startswith("fleet."):
        fleet = True
        flow_ref = flow_ref[len("fleet."):]
    if flow_ref is not None:
        b = _resolve_action_flow(policy, bindings, flow_ref, what)
        if fleet or b.flow.is_global():
            return _fleet_key(canon, b.channel, cond)
        return f"{b.stage}.{b.channel}.{canon}", None
    if fleet:
        return _fleet_key(canon, None, cond)
    stage = _resolve_stage(policy, None, infos, default_stage, what)
    return f"{stage}.{canon}", None


def _lower_trigger(
    policy: Policy,
    spec: TriggerSpec,
    bindings: Dict[str, _FlowBinding],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
) -> CompiledTrigger:
    what = f"trigger {spec.name!r}"
    metric_key, agg_override = _resolve_metric_key(
        policy, spec.when, bindings, infos, default_stage, what
    )
    fire: Dict[str, List[Any]] = {}
    release: Dict[str, List[Any]] = {}
    for action in spec.do:
        for stage, rules in _lower_action(policy, bindings, action, what, infos):
            fire.setdefault(stage, []).extend(rules)
    for action in spec.release:
        for stage, rules in _lower_action(policy, bindings, action, what, infos):
            release.setdefault(stage, []).extend(rules)
    return CompiledTrigger(
        policy=policy.name,
        name=spec.name,
        metric_key=metric_key,
        agg=agg_override or spec.when.agg,
        op=spec.when.op,
        value=spec.when.value,
        window=spec.when.window,
        hysteresis=spec.hysteresis,
        cooldown=spec.cooldown,
        fire_rules=fire,
        release_rules=release,
    )


# --------------------------------------------------------------------------- #
# objectives                                                                   #
# --------------------------------------------------------------------------- #
def _flow_specs(bindings: Dict[str, _FlowBinding]) -> Dict[str, Any]:
    """Flow name → FlowSpec (stage-scoped) or list of FlowSpecs (global:
    one member per registered stage — FairShareControl splits the flow's
    granted rate across them every step)."""
    from repro.core.algorithms import FlowSpec

    out: Dict[str, Any] = {}
    for name, b in bindings.items():
        specs = [
            FlowSpec(stage=stage, channel=b.channel, object_id=b.drl_object_id or "0")
            for stage in b.member_stages
        ]
        out[name] = specs[0] if len(specs) == 1 else specs
    return out


def _lower_objective(policy: Policy, bindings: Dict[str, _FlowBinding]):
    from repro.core.algorithms import FairShareControl, TailLatencyControl

    from .dsl import parse_duration, parse_quantity

    obj = policy.objective
    params = obj.params_dict()
    what = f"objective {obj.kind!r}"
    flows = _flow_specs(bindings)

    if obj.kind in ("fairshare", "fair_share", "max_min_fair_share"):
        demands_raw = params.get("demands")
        if not demands_raw:
            raise PolicyError(f"{what}: needs 'demands' (flow → guaranteed bandwidth)")
        demands: Dict[str, float] = {}
        for name, qty in dict(demands_raw).items():
            if name not in bindings:
                raise PolicyError(f"{what}: demand for undeclared flow {name!r}")
            demands[name] = parse_quantity(qty)
        capacity = params.get("capacity") or params.get("max_bandwidth")
        if capacity is None:
            raise PolicyError(f"{what}: needs 'capacity'")
        return FairShareControl.from_policy(
            {
                "demands": demands,
                "capacity": parse_quantity(capacity),
                "loop_interval": parse_duration(params.get("loop_interval", 0.1)),
            },
            {n: flows[n] for n in demands},
        )

    if obj.kind in ("tail_latency", "silk"):
        roles = {}
        for role in ("fg", "flush", "l0"):
            ref = params.get(role)
            if ref is None or ref not in bindings:
                raise PolicyError(f"{what}: needs '{role}' naming a declared flow")
            if isinstance(flows[ref], list):
                raise PolicyError(
                    f"{what}: role '{role}' cannot use global flow {ref!r} "
                    "(tail-latency roles are per-stage; only fairshare demands span stages)"
                )
            roles[role] = flows[ref]
        ln_refs = params.get("ln") or []
        if isinstance(ln_refs, str):
            ln_refs = [r for r in ln_refs.split(",") if r]
        for r in ln_refs:
            if r not in bindings:
                raise PolicyError(f"{what}: 'ln' names undeclared flow {r!r}")
            if isinstance(flows[r], list):
                raise PolicyError(
                    f"{what}: 'ln' cannot use global flow {r!r} "
                    "(tail-latency roles are per-stage; only fairshare demands span stages)"
                )
        capacity = params.get("capacity") or params.get("kvs_bandwidth")
        if capacity is None:
            raise PolicyError(f"{what}: needs 'capacity'")
        return TailLatencyControl.from_policy(
            {
                **roles,
                "ln": [flows[r] for r in ln_refs],
                "capacity": parse_quantity(capacity),
                "min_bandwidth": parse_quantity(params.get("min_bandwidth", params.get("min", 10 * (1 << 20)))),
                "loop_interval": parse_duration(params.get("loop_interval", 0.1)),
            }
        )

    raise PolicyError(f"{what}: unknown objective kind (known: fairshare, tail_latency)")


# --------------------------------------------------------------------------- #
# atomic replace: stage-info pruning + install-set diffing                     #
# --------------------------------------------------------------------------- #
#: per-kind params that ``obj_config`` applies faithfully — a same-kind object
#: update whose changed params all fall in this set lowers to an in-place
#: EnforcementRule retune. Anything else (unknown kinds, non-configurable
#: params like a DRL ``min_rate``, merge-only params like a priority gate's
#: ``priority_of``, or a param *added or removed* between versions — neither
#: direction is expressible through obj_config) falls back to an
#: atomic object-slot swap: ``create_object`` replaces the slot in one store,
#: so the data path sees old-then-new with no gap — at the cost of internal
#: state (e.g. accumulated token debt) restarting fresh.
RETUNE_KEYS: Dict[str, frozenset] = {
    "drl": frozenset({"rate", "refill_period"}),
    "noop": frozenset({"copy_content"}),
    "priority_gate": frozenset({"low_hold"}),
}

def _retunable(kind: Optional[str], old_params: Mapping[str, Any], new_params: Mapping[str, Any]) -> bool:
    if set(old_params) != set(new_params):
        # a param removed (or ADDED — its rollback would need to unset it,
        # which obj_config cannot express) forces the slot-swap path
        return False
    changed = {k for k in old_params if old_params[k] != new_params[k]}
    return changed <= RETUNE_KEYS.get(kind or "", frozenset())


def _freeze_match(match: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(match.items()))


def _install_key(rule: Any) -> Optional[Tuple]:
    """Identity of the data-plane entity an install rule creates."""
    if isinstance(rule, HousekeepingRule):
        if rule.op == "create_channel":
            return ("chan", rule.channel)
        if rule.op == "create_object":
            return ("obj", rule.channel, rule.object_id)
        if rule.op == "install_filter":
            return ("filter", rule.channel, rule.object_id)
        return None
    if isinstance(rule, DifferentiationRule):
        return ("route", rule.channel, _freeze_match(rule.match), rule.object_id)
    return None


def _teardown_key(rule: Any) -> Optional[Tuple]:
    """Identity of the entity a teardown rule destroys (mirror of
    :func:`_install_key`, so removals can be matched against carried-over
    installs)."""
    if isinstance(rule, HousekeepingRule):
        if rule.op == "remove_channel":
            return ("chan", rule.channel)
        if rule.op == "remove_object":
            return ("obj", rule.channel, rule.object_id)
        if rule.op == "remove_filter":
            return ("filter", rule.channel, rule.object_id)
        if rule.op == "remove_route":
            return ("route", rule.channel, _freeze_match(rule.params.get("match") or {}), rule.object_id)
    return None


def _undo_for_install(rule: Any) -> Any:
    """The inverse of one install rule (rollback of a half-applied delta)."""
    if isinstance(rule, HousekeepingRule):
        if rule.op == "create_channel":
            return HousekeepingRule(op="remove_channel", channel=rule.channel)
        if rule.op == "create_object":
            return HousekeepingRule(op="remove_object", channel=rule.channel, object_id=rule.object_id)
        if rule.op == "install_filter":
            return HousekeepingRule(op="remove_filter", channel=rule.channel, object_id=rule.object_id)
    if isinstance(rule, DifferentiationRule):
        return HousekeepingRule(
            op="remove_route", channel=rule.channel, object_id=rule.object_id,
            params={"match": dict(rule.match)},
        )
    return None


def infos_without_policy(
    infos: Mapping[str, Mapping[str, Any]], owned: CompiledPolicy
) -> Dict[str, Dict[str, Any]]:
    """A copy of live ``stage_info()`` maps with every channel/object created
    by ``owned`` removed — what the stages would look like had the policy
    never been installed. Compiling a *replacement* policy against this view
    (instead of the live one) means (a) the new version re-claims entities
    the old version owns without tripping the refusing-to-replace check, and
    (b) ownership transfers: the new compile emits create/teardown rules for
    them, which the delta then reconciles against what already exists.
    """
    # keys are stage-qualified: the old policy owning channel "io" on stage
    # s1 must not strip a same-named (foreign) channel from stage s2's view
    owned_keys = {
        (stage, k)
        for stage, rules in owned.install.items()
        for r in rules
        if (k := _install_key(r)) is not None
    }
    out: Dict[str, Dict[str, Any]] = {}
    for stage, info in infos.items():
        channels = {}
        for ch_name, ch in (info.get("channels") or {}).items():
            if (stage, ("chan", ch_name)) in owned_keys:
                continue
            objects = {
                oid: o
                for oid, o in (ch.get("objects") or {}).items()
                if (stage, ("obj", ch_name, oid)) not in owned_keys
            }
            channels[ch_name] = {**ch, "objects": objects}
            if ch.get("filters"):
                channels[ch_name]["filters"] = {
                    fid: f
                    for fid, f in ch["filters"].items()
                    if (stage, ("filter", ch_name, fid)) not in owned_keys
                }
        out[stage] = {**info, "channels": channels}
    return out


@dataclass
class PolicyDelta:
    """The minimal rule program turning installed policy state ``old`` into
    ``new`` with zero enforcement gap. ``ops`` is an ordered list of
    ``(stage, rule, undo)``: adds and in-place updates first (an
    unchanged entity is never touched; a same-kind object update lowers to
    an ``EnforcementRule`` so the live object is retuned, not recreated),
    then removals of entities only the old version owned. ``undo``
    (None, one rule, or a list of rules — a removed channel's undo must
    re-create the channel AND its objects) reverts that op if a later one
    fails."""

    ops: List[Tuple[str, Any, Optional[Any]]] = field(default_factory=list)


def diff_policies(old: CompiledPolicy, new: CompiledPolicy) -> PolicyDelta:
    """Compute the delta applied by ``install_policy(..., replace=True)``.

    Contract (the zero-gap property): at every instant during application,
    every entity present in *either* version is live and configured per the
    old or the new policy — entities shared by both versions are updated in
    place (``obj_config`` / atomic object-slot swap), never removed and
    recreated.
    """
    delta = PolicyDelta()
    old_by_stage: Dict[str, Dict[Tuple, Any]] = {}
    # stage routing tables are keyed by (mask, classifier-token) and are
    # channel-BLIND: a route's identity for diffing purposes is its match (+
    # object_id), not its target channel. A flow re-homed to a new channel is
    # an overwrite of the same entry, and the old remove_route must be
    # suppressed or it would delete the entry the new version just installed.
    old_routes_by_stage: Dict[str, Dict[Tuple, Any]] = {}
    for stage, rules in old.install.items():
        table = old_by_stage.setdefault(stage, {})
        routes = old_routes_by_stage.setdefault(stage, {})
        for r in rules:
            k = _install_key(r)
            if k is not None:
                table[k] = r
                if k[0] == "route":
                    routes[(k[2], k[3])] = r

    new_keys_by_stage: Dict[str, set] = {}
    new_routes_by_stage: Dict[str, set] = {}
    for stage, rules in new.install.items():
        keys = new_keys_by_stage[stage] = {
            k for r in rules if (k := _install_key(r)) is not None
        }
        new_routes_by_stage[stage] = {(k[2], k[3]) for k in keys if k[0] == "route"}
        old_by_key = old_by_stage.get(stage, {})
        old_routes = old_routes_by_stage.get(stage, {})
        for rule in rules:
            key = _install_key(rule)
            old_rule = old_by_key.get(key) if key is not None else None
            if old_rule == rule:
                continue  # identical entity: never touched, zero gap
            if key is not None and key[0] == "route" and old_rule is None:
                prior = old_routes.get((key[2], key[3]))
                if prior is not None:
                    # re-homed flow: installing the new route overwrites the
                    # old entry in place (no gap); undo re-points it back to
                    # the old channel rather than deleting it
                    delta.ops.append((stage, rule, prior))
                    continue
            if old_rule is not None and key[0] == "filter":
                # install_filter replaces the slot atomically, keeping its
                # chain position — no gap; undo re-installs the old spec
                delta.ops.append((stage, rule, old_rule))
                continue
            if old_rule is not None and key[0] == "obj":
                if old_rule.object_kind == rule.object_kind and _retunable(
                    rule.object_kind, old_rule.params, rule.params
                ):
                    # same object, config-applicable param change: retune the
                    # live object in place (state continuity preserved)
                    delta.ops.append(
                        (
                            stage,
                            EnforcementRule(
                                channel=rule.channel, object_id=rule.object_id, state=dict(rule.params)
                            ),
                            EnforcementRule(
                                channel=rule.channel,
                                object_id=rule.object_id,
                                state=dict(old_rule.params),
                            ),
                        )
                    )
                    continue
                # kind change, or params obj_config cannot apply faithfully:
                # create_object atomically swaps the channel's object slot
                # (the data path sees old until the swap, then new — no gap)
                delta.ops.append((stage, rule, old_rule))
                continue
            delta.ops.append((stage, rule, _undo_for_install(rule)))

    # removals: entities the old version created that the new one does not
    # claim — expressed through the old teardown program so ordering (routes
    # before objects before channels) is preserved. Applied last, so a flow
    # being dropped stays governed by the old rules until everything new is
    # in place.
    for stage, rules in old.teardown.items():
        new_keys = new_keys_by_stage.get(stage, set())
        new_routes = new_routes_by_stage.get(stage, set())
        old_by_key = old_by_stage.get(stage, {})
        covered: set = set()
        for td in rules:
            key = _teardown_key(td)
            if key is None or key in new_keys:
                continue
            if key[0] == "route" and (key[2], key[3]) in new_routes:
                # the match is claimed by the new version (possibly under a
                # different channel): remove_route is channel-blind and would
                # delete the entry the delta just installed
                continue
            covered.add(key)
            undo: Any = old_by_key.get(key)
            if key[0] == "chan":
                # undoing a channel removal must restore its objects too —
                # owned channels carry no per-object teardown (the channel
                # removal subsumes them), so nothing else re-creates them
                undo = [undo] + [
                    r for k, r in old_by_key.items() if k[0] == "obj" and k[1] == key[1]
                ]
            delta.ops.append((stage, td, undo))
        # objects/filters dropped from a SURVIVING channel have no teardown
        # rule to reuse (owned channels' removal subsumes them, but here the
        # channel lives on): synthesize the remove, or the stale entity would
        # keep enforcing forever
        for key, old_rule in old_by_key.items():
            if key[0] not in ("obj", "filter") or key in new_keys or key in covered:
                continue
            if ("chan", key[1]) in covered:
                continue  # whole channel is going away; entity dies with it
            op = "remove_object" if key[0] == "obj" else "remove_filter"
            delta.ops.append(
                (
                    stage,
                    HousekeepingRule(op=op, channel=key[1], object_id=key[2]),
                    old_rule,
                )
            )
    return delta
