"""Policy compiler: lower a typed :class:`Policy` to wire-serializable rules.

Compilation is where a policy meets reality: every flow, action, trigger and
objective is resolved against the registered stages' ``stage_info()`` so that
unknown stages, channels, enforcement objects, classifiers and metrics fail
**at compile time** — never in the control loop. The output is a
:class:`CompiledPolicy`:

* ``install``  — ordered housekeeping + differentiation rules per stage,
* ``teardown`` — the inverse rules (remove routes/objects/channels we made),
* ``triggers`` — :class:`CompiledTrigger` entries for the trigger engine with
  their fire/release rules already lowered,
* ``algorithm`` — a ControlAlgorithm when the policy declares an objective
  (fair share / tail latency), built through the algorithms' ``from_policy``
  constructors so hand-coded and policy-driven control are the same code.

Everything in ``install``/``teardown``/trigger rules is a plain rule dataclass
(:mod:`repro.core.rules`), so a compiled policy applies identically through a
local handle or the UNIX-socket transport.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Tuple

from repro.core.objects import OBJECT_KINDS
from repro.core.rules import DifferentiationRule, EnforcementRule, HousekeepingRule

from .dsl import (
    Action,
    Condition,
    Flow,
    ObjectSpec,
    Policy,
    PolicyError,
    TriggerSpec,
    parse_quantity,
)
from .triggers import CompiledTrigger

#: builtin per-channel metric fields derivable from StatsSnapshot collects
BUILTIN_METRICS = ("throughput", "iops", "wait_ms", "inflight", "ops", "bytes")
#: accepted aliases for builtin metric names
METRIC_ALIASES = {
    "bandwidth": "throughput",
    "latency_ms": "wait_ms",
    **{m: m for m in BUILTIN_METRICS},
}

#: a demoted flow's DRL runs at provisioned_rate / DEMOTE_FACTOR (floor 1.0)
DEMOTE_FACTOR = 10.0


@dataclass
class _FlowBinding:
    """A flow resolved to its physical location + DRL provisioning."""

    flow: Flow
    stage: str
    channel: str
    drl_object_id: Optional[str] = None
    provisioned_rate: Optional[float] = None
    demote_rate: Optional[float] = None


@dataclass
class CompiledPolicy:
    policy: Policy
    install: Dict[str, List[Any]] = field(default_factory=dict)
    teardown: Dict[str, List[Any]] = field(default_factory=dict)
    triggers: List[CompiledTrigger] = field(default_factory=list)
    algorithm: Optional[Any] = None

    @property
    def name(self) -> str:
        return self.policy.name

    def stages(self) -> List[str]:
        out = set(self.install) | set(self.teardown)
        for t in self.triggers:
            out.update(t.fire_rules)
            out.update(t.release_rules)
        return sorted(out)

    def summary(self) -> Dict[str, Any]:
        return {
            "policy": self.policy.name,
            "stages": self.stages(),
            "flows": [f.name for f in self.policy.flows],
            "triggers": [t.qualified_name for t in self.triggers],
            "objective": self.policy.objective.kind if self.policy.objective else None,
        }


def compile_policy(
    policy: Policy,
    infos: Optional[Mapping[str, Mapping[str, Any]]] = None,
    default_stage: Optional[str] = None,
) -> CompiledPolicy:
    """Lower ``policy`` to rules, validating against ``infos`` when given.

    ``infos`` maps stage name → ``stage_info()`` dict (from either transport).
    When ``infos`` is None the compile is *offline*: structure is checked but
    existence of stages/channels is deferred to install time.
    """
    cp = CompiledPolicy(policy=policy)
    bindings = _bind_flows(policy, infos, default_stage)

    for b in bindings.values():
        _lower_flow(cp, b, infos)

    for spec in policy.triggers:
        cp.triggers.append(_lower_trigger(policy, spec, bindings, infos, default_stage))

    if policy.objective is not None:
        cp.algorithm = _lower_objective(policy, bindings)
    return cp


# --------------------------------------------------------------------------- #
# flows                                                                        #
# --------------------------------------------------------------------------- #
def _resolve_stage(
    policy: Policy,
    flow_stage: Optional[str],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
    what: str,
) -> str:
    stage = flow_stage or policy.stage or default_stage
    if stage is None:
        if infos is not None and len(infos) == 1:
            return next(iter(infos))
        raise PolicyError(
            f"{what}: no stage named (set the policy 'stage', the flow 'stage', "
            "or register exactly one stage)"
        )
    if infos is not None and stage not in infos:
        raise PolicyError(f"{what}: unknown stage {stage!r} (registered: {sorted(infos)})")
    return stage


def _bind_flows(
    policy: Policy,
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
) -> Dict[str, _FlowBinding]:
    bindings: Dict[str, _FlowBinding] = {}
    for flow in policy.flows:
        stage = _resolve_stage(policy, flow.stage, infos, default_stage, f"flow {flow.name!r}")
        b = _FlowBinding(flow=flow, stage=stage, channel=flow.channel_name())
        for obj in flow.objects:
            if obj.kind not in OBJECT_KINDS:
                raise PolicyError(
                    f"flow {flow.name!r}: unknown object kind {obj.kind!r} "
                    f"(known: {sorted(OBJECT_KINDS)})"
                )
            _dry_construct(flow, obj)
            if obj.kind == "drl":
                params = obj.params_dict()
                if "rate" not in params:
                    raise PolicyError(f"flow {flow.name!r}: drl object needs a 'rate'")
                b.drl_object_id = obj.object_id
                b.provisioned_rate = parse_quantity(params["rate"])
                b.demote_rate = parse_quantity(
                    params.get("demote_rate") or max(b.provisioned_rate / DEMOTE_FACTOR, 1.0)
                )
        bindings[flow.name] = b
    return bindings


def _dry_construct(flow: Flow, obj: ObjectSpec) -> None:
    """Validate enforcement-object params by constructing a throwaway
    instance, so a typo'd or bad-valued param fails at compile time instead
    of mid-install (which would leave partial stage state). ImportError is
    deliberately not treated as a compile error: an object whose optional
    dependency is missing fails identically at install, and compiling a
    policy should not require the dependency."""
    params = obj.params_dict()
    params.pop("demote_rate", None)
    try:
        OBJECT_KINDS[obj.kind](**params)
    except (TypeError, ValueError) as exc:
        raise PolicyError(
            f"flow {flow.name!r}: bad params for {obj.kind!r} object "
            f"{obj.object_id!r}: {exc}"
        ) from None
    except ImportError:
        pass


def _lower_flow(
    cp: CompiledPolicy, b: _FlowBinding, infos: Optional[Mapping[str, Any]]
) -> None:
    install = cp.install.setdefault(b.stage, [])
    teardown: List[Any] = []
    existing = (infos or {}).get(b.stage, {}).get("channels", {}) if infos is not None else {}
    channel_exists = b.channel in existing

    if not channel_exists:
        install.append(HousekeepingRule(op="create_channel", channel=b.channel))
    for obj in b.flow.objects:
        params = obj.params_dict()
        params.pop("demote_rate", None)  # compile-time knob, not an obj_init param
        if channel_exists:
            have = existing.get(b.channel, {}).get("objects", {})
            prior = have.get(obj.object_id)
            if prior is not None and prior.get("kind") not in (None, "noop"):
                raise PolicyError(
                    f"flow {b.flow.name!r}: object {obj.object_id!r} already exists on "
                    f"channel {b.channel!r} (kind {prior.get('kind')!r}); refusing to replace"
                )
        install.append(
            HousekeepingRule(
                op="create_object",
                channel=b.channel,
                object_id=obj.object_id,
                object_kind=obj.kind,
                params=params,
            )
        )
        if channel_exists:  # channel outlives the policy: remove objects one by one
            teardown.append(
                HousekeepingRule(op="remove_object", channel=b.channel, object_id=obj.object_id)
            )
    match = b.flow.match_dict()
    install.append(DifferentiationRule(channel=b.channel, match=match))
    teardown.append(
        HousekeepingRule(op="remove_route", channel=b.channel, params={"match": match})
    )
    if not channel_exists:
        teardown.append(HousekeepingRule(op="remove_channel", channel=b.channel))
    cp.teardown.setdefault(b.stage, []).extend(teardown)


# --------------------------------------------------------------------------- #
# actions                                                                      #
# --------------------------------------------------------------------------- #
def _resolve_action_flow(
    policy: Policy, bindings: Dict[str, _FlowBinding], ref: Optional[str], what: str
) -> _FlowBinding:
    if ref is None:
        raise PolicyError(f"{what}: action needs a target flow")
    if ref in bindings:
        return bindings[ref]
    if "=" in ref:  # "tenant=analytics" → the flow with exactly that match
        from .dsl import _canon_match  # noqa: PLC0415 — shared canonicalization

        key, _, val = ref.partition("=")
        want = _canon_match({key: val})
        for b in bindings.values():
            if b.flow.match == want:
                return b
    raise PolicyError(
        f"{what}: unknown flow {ref!r} (declared: {sorted(bindings)})"
    )


def _lower_action(
    policy: Policy,
    bindings: Dict[str, _FlowBinding],
    action: Action,
    what: str,
    infos: Optional[Mapping[str, Any]],
) -> Tuple[str, List[Any]]:
    """Returns (stage, rules) for one action."""
    if action.op == "set":
        b = _resolve_action_flow(policy, bindings, action.flow, what)
        state = action.state_dict()
        if not state:
            raise PolicyError(f"{what}: 'set' action with empty state")
        _check_object(infos, b, action.object_id, what)
        return b.stage, [
            EnforcementRule(channel=b.channel, object_id=action.object_id, state=state)
        ]
    if action.op in ("demote", "promote"):
        b = _resolve_action_flow(policy, bindings, action.flow, what)
        if b.drl_object_id is None:
            raise PolicyError(
                f"{what}: {action.op} targets flow {b.flow.name!r} which provisions no DRL "
                "(add 'limit bandwidth …' to the flow)"
            )
        rate = b.demote_rate if action.op == "demote" else b.provisioned_rate
        return b.stage, [
            EnforcementRule(channel=b.channel, object_id=b.drl_object_id, state={"rate": rate})
        ]
    raise PolicyError(f"{what}: unknown action op {action.op!r}")


def _check_object(
    infos: Optional[Mapping[str, Any]], b: _FlowBinding, object_id: str, what: str
) -> None:
    """An action's target object must be provisioned by the policy or already
    live on the stage (when stage info is available to check)."""
    if any(o.object_id == object_id for o in b.flow.objects):
        return
    if infos is None:
        return
    have = infos.get(b.stage, {}).get("channels", {}).get(b.channel, {}).get("objects", {})
    if object_id not in have:
        raise PolicyError(
            f"{what}: object {object_id!r} not provisioned on flow {b.flow.name!r} "
            f"and not present on stage {b.stage!r} channel {b.channel!r}"
        )


# --------------------------------------------------------------------------- #
# triggers                                                                     #
# --------------------------------------------------------------------------- #
def _resolve_metric_key(
    policy: Policy,
    cond: Condition,
    bindings: Dict[str, _FlowBinding],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
    what: str,
) -> str:
    if "." in cond.metric:  # fully-qualified registry key — pluggable, pass through
        return cond.metric
    canon = METRIC_ALIASES.get(cond.metric)
    if canon is None:
        raise PolicyError(
            f"{what}: unknown metric {cond.metric!r} "
            f"(builtins: {sorted(set(METRIC_ALIASES))}; registry metrics use dotted names)"
        )
    if cond.flow is not None:
        b = _resolve_action_flow(policy, bindings, cond.flow, what)
        return f"{b.stage}.{b.channel}.{canon}"
    stage = _resolve_stage(policy, None, infos, default_stage, what)
    return f"{stage}.{canon}"


def _lower_trigger(
    policy: Policy,
    spec: TriggerSpec,
    bindings: Dict[str, _FlowBinding],
    infos: Optional[Mapping[str, Any]],
    default_stage: Optional[str],
) -> CompiledTrigger:
    what = f"trigger {spec.name!r}"
    metric_key = _resolve_metric_key(policy, spec.when, bindings, infos, default_stage, what)
    fire: Dict[str, List[Any]] = {}
    release: Dict[str, List[Any]] = {}
    for action in spec.do:
        stage, rules = _lower_action(policy, bindings, action, what, infos)
        fire.setdefault(stage, []).extend(rules)
    for action in spec.release:
        stage, rules = _lower_action(policy, bindings, action, what, infos)
        release.setdefault(stage, []).extend(rules)
    return CompiledTrigger(
        policy=policy.name,
        name=spec.name,
        metric_key=metric_key,
        agg=spec.when.agg,
        op=spec.when.op,
        value=spec.when.value,
        window=spec.when.window,
        hysteresis=spec.hysteresis,
        cooldown=spec.cooldown,
        fire_rules=fire,
        release_rules=release,
    )


# --------------------------------------------------------------------------- #
# objectives                                                                   #
# --------------------------------------------------------------------------- #
def _flow_specs(bindings: Dict[str, _FlowBinding]) -> Dict[str, Any]:
    from repro.core.algorithms import FlowSpec

    return {
        name: FlowSpec(stage=b.stage, channel=b.channel, object_id=b.drl_object_id or "0")
        for name, b in bindings.items()
    }


def _lower_objective(policy: Policy, bindings: Dict[str, _FlowBinding]):
    from repro.core.algorithms import FairShareControl, TailLatencyControl

    from .dsl import parse_duration, parse_quantity

    obj = policy.objective
    params = obj.params_dict()
    what = f"objective {obj.kind!r}"
    flows = _flow_specs(bindings)

    if obj.kind in ("fairshare", "fair_share", "max_min_fair_share"):
        demands_raw = params.get("demands")
        if not demands_raw:
            raise PolicyError(f"{what}: needs 'demands' (flow → guaranteed bandwidth)")
        demands: Dict[str, float] = {}
        for name, qty in dict(demands_raw).items():
            if name not in bindings:
                raise PolicyError(f"{what}: demand for undeclared flow {name!r}")
            demands[name] = parse_quantity(qty)
        capacity = params.get("capacity") or params.get("max_bandwidth")
        if capacity is None:
            raise PolicyError(f"{what}: needs 'capacity'")
        return FairShareControl.from_policy(
            {
                "demands": demands,
                "capacity": parse_quantity(capacity),
                "loop_interval": parse_duration(params.get("loop_interval", 0.1)),
            },
            {n: flows[n] for n in demands},
        )

    if obj.kind in ("tail_latency", "silk"):
        roles = {}
        for role in ("fg", "flush", "l0"):
            ref = params.get(role)
            if ref is None or ref not in bindings:
                raise PolicyError(f"{what}: needs '{role}' naming a declared flow")
            roles[role] = flows[ref]
        ln_refs = params.get("ln") or []
        if isinstance(ln_refs, str):
            ln_refs = [r for r in ln_refs.split(",") if r]
        for r in ln_refs:
            if r not in bindings:
                raise PolicyError(f"{what}: 'ln' names undeclared flow {r!r}")
        capacity = params.get("capacity") or params.get("kvs_bandwidth")
        if capacity is None:
            raise PolicyError(f"{what}: needs 'capacity'")
        return TailLatencyControl.from_policy(
            {
                **roles,
                "ln": [flows[r] for r in ln_refs],
                "capacity": parse_quantity(capacity),
                "min_bandwidth": parse_quantity(params.get("min_bandwidth", params.get("min", 10 * (1 << 20)))),
                "loop_interval": parse_duration(params.get("loop_interval", 0.1)),
            }
        )

    raise PolicyError(f"{what}: unknown objective kind (known: fairshare, tail_latency)")
