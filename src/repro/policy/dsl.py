"""Declarative policy DSL (the missing Crystal-style layer over PAIO rules).

A *policy* is what an administrator writes; *rules* are what stages execute.
Policies are dict/JSON-native — the canonical form is a plain dict parsed into
typed, frozen dataclasses by :func:`policy_from_dict` — with a compact text
front-end (:func:`parse_policy_text`) for the common cases::

    policy serve_guard stage serve
    for tenant=analytics: limit bandwidth 100MiB/s
    for request_context=bg_compaction_LN as compaction: limit bandwidth 50MiB/s
    when p99_latency_ms > 50 window 2s cooldown 1s release 35: demote compaction
    objective fairshare capacity 600MiB/s demands analytics=400MiB/s,compaction=200MiB/s

Statement kinds:

* ``for <classifier>=<value>[ ...] [as <name>]: <action>[; <action>]`` —
  declares a *flow*: a channel fed by a differentiation match, provisioned
  with enforcement objects (``limit bandwidth`` creates a DRL).
* ``when <metric> <op> <number> [...]: <action>[; <action>]`` — a
  metrics-driven *trigger* evaluated by the control plane every collect tick,
  with sliding-window aggregation, hysteresis and cooldown.
* ``objective <kind> ...`` — a closed-loop control objective (max-min fair
  share / tail-latency) compiled to the existing ControlAlgorithm classes.

The DSL is deliberately *not* Turing-complete: everything lowers to the wire
rule types of :mod:`repro.core.rules`, so a policy can always be shipped to a
remote stage over the UDS transport with identical semantics.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.core.context import RequestType
from repro.core.rules import CLASSIFIERS


class PolicyError(ValueError):
    """Raised on parse or compile errors — policies fail loudly, at load time."""


# --------------------------------------------------------------------------- #
# quantities                                                                   #
# --------------------------------------------------------------------------- #
_QTY_RE = re.compile(
    r"^\s*(?P<num>-?\d+(?:\.\d+)?)\s*(?P<unit>[KMGT]i?B|B)?\s*(?P<per>/s)?\s*$",
    re.IGNORECASE,
)
_TIME_RE = re.compile(r"^\s*(?P<num>-?\d+(?:\.\d+)?)\s*(?P<unit>ms|us|s|m|h)?\s*$")

_BYTE_SCALE = {
    "b": 1,
    "kib": 1 << 10, "kb": 1000,
    "mib": 1 << 20, "mb": 1000**2,
    "gib": 1 << 30, "gb": 1000**3,
    "tib": 1 << 40, "tb": 1000**4,
}
_TIME_SCALE = {"us": 1e-6, "ms": 1e-3, "s": 1.0, "m": 60.0, "h": 3600.0}


def parse_quantity(value: Any) -> float:
    """Parse a byte-rate / byte / bare-number quantity: ``"100MiB/s"`` →
    104857600.0, ``"4KiB"`` → 4096.0, ``250`` → 250.0."""
    if isinstance(value, (int, float)):
        return float(value)
    m = _QTY_RE.match(str(value))
    if not m:
        raise PolicyError(f"bad quantity {value!r} (expected e.g. 100MiB/s, 4KiB, 250)")
    num = float(m.group("num"))
    unit = (m.group("unit") or "").lower()
    return num * _BYTE_SCALE.get(unit, 1)


def parse_duration(value: Any) -> float:
    """Parse a duration into seconds: ``"500ms"`` → 0.5, ``2`` → 2.0."""
    if isinstance(value, (int, float)):
        return float(value)
    m = _TIME_RE.match(str(value))
    if not m:
        raise PolicyError(f"bad duration {value!r} (expected e.g. 500ms, 2s)")
    return float(m.group("num")) * _TIME_SCALE.get((m.group("unit") or "s").lower(), 1.0)


#: accepted classifier aliases in policy matches (DSL sugar → Context field)
CLASSIFIER_ALIASES = {
    "workflow": "workflow_id",
    "context": "request_context",
    "type": "request_type",
    **{c: c for c in CLASSIFIERS},
}


def _canon_match(match: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    out = []
    for key, val in match.items():
        canon = CLASSIFIER_ALIASES.get(key)
        if canon is None:
            raise PolicyError(
                f"unknown classifier {key!r} in match (known: {sorted(set(CLASSIFIER_ALIASES))})"
            )
        if canon == "request_type" and isinstance(val, str):
            # symbolic verbs ("read", "write", …) must land on the same int
            # code the data plane hashes, or the route would silently never hit
            if val.isdigit():
                val = int(val)
            else:
                try:
                    val = int(RequestType[val])
                except KeyError:
                    raise PolicyError(
                        f"unknown request_type {val!r} "
                        f"(known: {[t.name for t in RequestType]})"
                    ) from None
        if canon == "workflow_id" and isinstance(val, str) and val.lstrip("-").isdigit():
            val = int(val)
        out.append((canon, val))
    return tuple(sorted(out))


# --------------------------------------------------------------------------- #
# typed policy model                                                           #
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class ObjectSpec:
    """One enforcement object provisioned on a flow's channel."""

    kind: str
    object_id: str = "0"
    params: Tuple[Tuple[str, Any], ...] = ()

    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)


@dataclass(frozen=True)
class FilterDecl:
    """One runtime-pluggable filter installed on a flow's channel.

    ``version`` 0 means "latest the stage's filter registry advertises" — the
    compiler pins it to a concrete version at compile time so the installed
    configuration is reproducible. ``filter_id`` is the instance slot on the
    channel (defaults to the filter name: one instance per kind)."""

    name: str
    version: int = 0
    filter_id: str = ""
    params: Tuple[Tuple[str, Any], ...] = ()

    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)

    def slot(self) -> str:
        return self.filter_id or self.name


@dataclass(frozen=True)
class Flow:
    """A named flow: classifier match → dedicated channel + objects.

    ``scope`` is ``"stage"`` (default: the flow lives on exactly one stage)
    or ``"global"`` — the flow is instantiated on **every** registered stage
    (one same-named channel per stage, same objects, same match), and a
    fair-share objective naming it guarantees its demand in *aggregate*
    across those instances (the fleet topology: many processes, one SLO).
    """

    name: str
    match: Tuple[Tuple[str, Any], ...]
    stage: Optional[str] = None  # None → the policy's default stage
    channel: Optional[str] = None  # None → flow name
    objects: Tuple[ObjectSpec, ...] = ()
    filters: Tuple[FilterDecl, ...] = ()
    scope: str = "stage"

    def match_dict(self) -> Dict[str, Any]:
        return dict(self.match)

    def channel_name(self) -> str:
        return self.channel or self.name

    def is_global(self) -> bool:
        return self.scope == "global"


@dataclass(frozen=True)
class Action:
    """One triggered (or provisioning) action against a flow's objects.

    ``op``:
      * ``set``     — push ``state`` into the target object (enf rule),
      * ``demote``  — throttle the flow's DRL to its demote floor,
      * ``promote`` — restore the flow's provisioned DRL rate.
    """

    op: str
    flow: Optional[str] = None
    object_id: str = "0"
    state: Tuple[Tuple[str, Any], ...] = ()

    def state_dict(self) -> Dict[str, Any]:
        return dict(self.state)


_AGGS = ("last", "mean", "min", "max", "rate", "p50", "p95", "p99")
_OPS = (">", ">=", "<", "<=", "==", "!=")


@dataclass(frozen=True)
class Condition:
    """A windowed metric predicate: ``agg(metric over window) op value``."""

    metric: str
    op: str
    value: float
    agg: str = "last"
    flow: Optional[str] = None  # builtin metrics resolve against this flow
    window: float = 1.0


@dataclass(frozen=True)
class TriggerSpec:
    """when-condition → actions, with hysteresis + cooldown (no flapping)."""

    name: str
    when: Condition
    do: Tuple[Action, ...]
    release: Tuple[Action, ...] = ()
    #: release band width, in metric units: a fired ``>`` trigger only resets
    #: once agg drops below ``value - hysteresis`` (mirrored for ``<``)
    hysteresis: float = 0.0
    #: minimum seconds between consecutive fires
    cooldown: float = 0.0


@dataclass(frozen=True)
class Objective:
    """Closed-loop objective lowered to a ControlAlgorithm (fairshare / …)."""

    kind: str
    params: Tuple[Tuple[str, Any], ...] = ()

    def params_dict(self) -> Dict[str, Any]:
        return dict(self.params)


@dataclass(frozen=True)
class Policy:
    name: str
    stage: Optional[str] = None
    flows: Tuple[Flow, ...] = ()
    triggers: Tuple[TriggerSpec, ...] = ()
    objective: Optional[Objective] = None
    #: when set, ``stage`` is a *logical* sharded stage: its N shard stages
    #: (``<stage>/0`` … ``<stage>/N-1``, the shard-router naming convention)
    #: must all be registered, and ``scope: global`` flows bind to exactly
    #: those members instead of every stage on the plane
    shards: Optional[int] = None

    def flow(self, name: str) -> Optional[Flow]:
        for f in self.flows:
            if f.name == name:
                return f
        return None


# --------------------------------------------------------------------------- #
# dict/JSON form                                                               #
# --------------------------------------------------------------------------- #
def _freeze(d: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    return tuple(sorted(d.items()))


def _object_from_dict(d: Mapping[str, Any]) -> ObjectSpec:
    if "kind" not in d:
        raise PolicyError(f"object spec missing 'kind': {d!r}")
    params = dict(d.get("params") or {})
    for key in ("rate", "demote_rate"):
        if key in params:
            params[key] = parse_quantity(params[key])
    return ObjectSpec(
        kind=str(d["kind"]),
        object_id=str(d.get("id", d.get("object_id", "0"))),
        params=_freeze(params),
    )


def _filter_from_dict(d: Mapping[str, Any]) -> FilterDecl:
    if "name" not in d:
        raise PolicyError(f"filter spec missing 'name': {d!r}")
    try:
        version = int(d.get("version", 0))
    except (TypeError, ValueError):
        raise PolicyError(f"filter version must be an integer, got {d.get('version')!r}") from None
    if version < 0:
        raise PolicyError(f"filter version must be >= 0, got {version}")
    return FilterDecl(
        name=str(d["name"]),
        version=version,
        filter_id=str(d.get("id", d.get("filter_id", ""))),
        params=_freeze(dict(d.get("params") or {})),
    )


def _action_from_dict(d: Mapping[str, Any]) -> Action:
    op = d.get("op") or d.get("action")
    if op not in ("set", "demote", "promote"):
        raise PolicyError(f"unknown action op {op!r} (known: set, demote, promote)")
    state = dict(d.get("state") or {})
    if "rate" in state:
        state["rate"] = parse_quantity(state["rate"])
    return Action(
        op=op,
        flow=d.get("flow"),
        object_id=str(d.get("object_id", "0")),
        state=_freeze(state),
    )


def _condition_from_dict(d: Mapping[str, Any]) -> Condition:
    metric, agg = str(d.get("metric", "")), str(d.get("agg", "last"))
    if not metric:
        raise PolicyError("trigger condition missing 'metric'")
    metric, prefix_agg = _split_agg_prefix(metric)
    if prefix_agg is not None:
        agg = prefix_agg if agg == "last" else agg
    if agg not in _AGGS:
        raise PolicyError(f"unknown aggregation {agg!r} (known: {_AGGS})")
    op = str(d.get("op", ">"))
    if op not in _OPS:
        raise PolicyError(f"unknown comparison {op!r} (known: {_OPS})")
    return Condition(
        metric=metric,
        op=op,
        value=parse_quantity(d.get("value", 0)),
        agg=agg,
        flow=d.get("flow"),
        window=parse_duration(d.get("window", 1.0)),
    )


def _trigger_from_dict(d: Mapping[str, Any], index: int) -> TriggerSpec:
    if "when" not in d:
        raise PolicyError(f"trigger missing 'when': {d!r}")
    do = tuple(_action_from_dict(a) for a in d.get("do") or ())
    if not do:
        raise PolicyError(f"trigger {d.get('name', index)!r} has no 'do' actions")
    return TriggerSpec(
        name=str(d.get("name", f"trigger{index}")),
        when=_condition_from_dict(d["when"]),
        do=do,
        release=tuple(_action_from_dict(a) for a in d.get("release") or ()),
        hysteresis=parse_quantity(d.get("hysteresis", 0)),
        cooldown=parse_duration(d.get("cooldown", 0)),
    )


def policy_from_dict(d: Mapping[str, Any]) -> Policy:
    """Parse the canonical dict/JSON form into a typed :class:`Policy`."""
    if not isinstance(d, Mapping):
        raise PolicyError(f"policy must be a mapping, got {type(d).__name__}")
    name = d.get("policy") or d.get("name")
    if not name:
        raise PolicyError("policy missing 'policy' (its name)")
    flows = []
    seen = set()
    for fd in d.get("flows") or ():
        if "match" not in fd or "name" not in fd:
            raise PolicyError(f"flow needs 'name' and 'match': {fd!r}")
        if fd["name"] in seen:
            raise PolicyError(f"duplicate flow name {fd['name']!r}")
        seen.add(fd["name"])
        scope = str(fd.get("scope", "stage"))
        if scope not in ("stage", "global"):
            raise PolicyError(
                f"flow {fd['name']!r}: unknown scope {scope!r} (known: stage, global)"
            )
        if scope == "global" and fd.get("stage"):
            raise PolicyError(
                f"flow {fd['name']!r}: 'scope: global' and an explicit 'stage' are "
                "mutually exclusive (a global flow spans every registered stage)"
            )
        filters = tuple(_filter_from_dict(x) for x in fd.get("filters") or ())
        slots = [flt.slot() for flt in filters]
        if len(slots) != len(set(slots)):
            raise PolicyError(
                f"flow {fd['name']!r}: duplicate filter slot (give each instance "
                "a distinct 'id' to install the same filter twice)"
            )
        flows.append(
            Flow(
                name=str(fd["name"]),
                match=_canon_match(fd["match"]),
                stage=fd.get("stage"),
                channel=fd.get("channel"),
                objects=tuple(_object_from_dict(o) for o in fd.get("objects") or ()),
                filters=filters,
                scope=scope,
            )
        )
    objective = None
    if d.get("objective"):
        od = dict(d["objective"])
        kind = od.pop("kind", None)
        if not kind:
            raise PolicyError("objective missing 'kind'")
        objective = Objective(kind=str(kind), params=_freeze(od))
    shards = d.get("shards")
    if shards is not None:
        try:
            shards = int(shards)
        except (TypeError, ValueError):
            raise PolicyError(f"'shards' must be an integer, got {d.get('shards')!r}") from None
        if shards < 1:
            raise PolicyError(f"'shards' must be >= 1, got {shards}")
        if not d.get("stage"):
            raise PolicyError("'shards' needs a policy-level 'stage' (the logical stage name)")
    return Policy(
        name=str(name),
        stage=d.get("stage"),
        flows=tuple(flows),
        triggers=tuple(_trigger_from_dict(td, i) for i, td in enumerate(d.get("triggers") or ())),
        objective=objective,
        shards=shards,
    )


def policy_to_dict(p: Policy) -> Dict[str, Any]:
    """Canonical dict form (JSON-serializable; round-trips via policy_from_dict)."""
    d: Dict[str, Any] = {"policy": p.name}
    if p.stage:
        d["stage"] = p.stage
    if p.shards is not None:
        d["shards"] = p.shards
    if p.flows:
        d["flows"] = [
            {
                "name": f.name,
                "match": f.match_dict(),
                **({"stage": f.stage} if f.stage else {}),
                **({"channel": f.channel} if f.channel else {}),
                **({"scope": f.scope} if f.scope != "stage" else {}),
                "objects": [
                    {"kind": o.kind, "id": o.object_id, "params": o.params_dict()}
                    for o in f.objects
                ],
                **(
                    {
                        "filters": [
                            {
                                "name": flt.name,
                                **({"version": flt.version} if flt.version else {}),
                                **({"id": flt.filter_id} if flt.filter_id else {}),
                                **({"params": flt.params_dict()} if flt.params else {}),
                            }
                            for flt in f.filters
                        ]
                    }
                    if f.filters
                    else {}
                ),
            }
            for f in p.flows
        ]
    if p.triggers:
        d["triggers"] = [
            {
                "name": t.name,
                "when": {
                    "metric": t.when.metric,
                    "op": t.when.op,
                    "value": t.when.value,
                    "agg": t.when.agg,
                    **({"flow": t.when.flow} if t.when.flow else {}),
                    "window": t.when.window,
                },
                "do": [_action_to_dict(a) for a in t.do],
                **({"release": [_action_to_dict(a) for a in t.release]} if t.release else {}),
                "hysteresis": t.hysteresis,
                "cooldown": t.cooldown,
            }
            for t in p.triggers
        ]
    if p.objective:
        d["objective"] = {"kind": p.objective.kind, **p.objective.params_dict()}
    return d


def _action_to_dict(a: Action) -> Dict[str, Any]:
    out: Dict[str, Any] = {"op": a.op}
    if a.flow:
        out["flow"] = a.flow
    if a.object_id != "0":
        out["object_id"] = a.object_id
    if a.state:
        out["state"] = a.state_dict()
    return out


# --------------------------------------------------------------------------- #
# compact text front-end                                                       #
# --------------------------------------------------------------------------- #
#: ``p99_latency_ms`` style shorthand → (metric, agg)
_AGG_PREFIX_RE = re.compile(r"^(p50|p95|p99|mean|max|min|rate)_(.+)$")


def _split_agg_prefix(metric: str) -> Tuple[str, Optional[str]]:
    m = _AGG_PREFIX_RE.match(metric)
    if m and "." not in metric:
        return m.group(2), m.group(1)
    return metric, None


def _parse_text_action(text: str, own_flow: Optional[str]) -> Action:
    toks = text.split()
    if not toks:
        raise PolicyError("empty action")
    verb = toks[0]
    if verb == "limit":
        # limit bandwidth 100MiB/s [on <flow>[.<oid>]]
        if len(toks) < 3 or toks[1] not in ("bandwidth", "rate", "iops"):
            raise PolicyError(f"bad limit action {text!r} (limit bandwidth <qty> [on <flow>])")
        flow, oid = _parse_on_clause(toks[3:], text, own_flow)
        return Action(op="set", flow=flow, object_id=oid, state=_freeze({"rate": parse_quantity(toks[2])}))
    if verb == "set":
        # set key=value[,key=value] [on <flow>[.<oid>]]
        if len(toks) < 2:
            raise PolicyError(f"bad set action {text!r}")
        state: Dict[str, Any] = {}
        for kv in toks[1].split(","):
            if "=" not in kv:
                raise PolicyError(f"bad set action {text!r} (need key=value)")
            k, v = kv.split("=", 1)
            try:
                state[k] = parse_quantity(v)
            except PolicyError:
                state[k] = v
        flow, oid = _parse_on_clause(toks[2:], text, own_flow)
        return Action(op="set", flow=flow, object_id=oid, state=_freeze(state))
    if verb in ("demote", "promote"):
        # demote <flow> | demote <classifier>=<value> (resolved at compile)
        target = toks[1] if len(toks) > 1 else own_flow
        if target is None:
            raise PolicyError(f"{verb} needs a flow: {text!r}")
        return Action(op=verb, flow=target)
    raise PolicyError(f"unknown action verb {verb!r} in {text!r}")


def _parse_text_filter(text: str) -> Dict[str, Any]:
    # filter <name>[@<version>] [id=<slot>] [k=v ...]
    toks = text.split()
    if len(toks) < 2:
        raise PolicyError(f"bad filter declaration {text!r} (filter <name>[@version] [k=v ...])")
    name, _, ver = toks[1].partition("@")
    out: Dict[str, Any] = {"name": name}
    if ver:
        if not ver.isdigit():
            raise PolicyError(f"bad filter version {ver!r} in {text!r} (expected an integer)")
        out["version"] = int(ver)
    params: Dict[str, Any] = {}
    for kv in toks[2:]:
        if "=" not in kv:
            raise PolicyError(f"bad filter param {kv!r} in {text!r} (need key=value)")
        k, v = kv.split("=", 1)
        if k == "id":
            out["id"] = v
            continue
        try:
            params[k] = int(v)
        except ValueError:
            try:
                params[k] = parse_quantity(v)
            except PolicyError:
                params[k] = v
    if params:
        out["params"] = params
    return out


def _parse_on_clause(toks, text: str, own_flow: Optional[str]):
    if not toks:
        return own_flow, "0"
    if toks[0] != "on" or len(toks) != 2:
        raise PolicyError(f"bad action tail {toks!r} in {text!r} (expected: on <flow>[.<oid>])")
    flow, _, oid = toks[1].partition(".")
    return flow, oid or "0"


def _flow_name_from_match(match: Tuple[Tuple[str, Any], ...]) -> str:
    return "_".join(str(v) for _, v in match) or "all"


_WHEN_RE = re.compile(
    r"^when\s+(?P<metric>\S+?)(?:@(?P<flow>\S+))?\s+(?P<op>>=|<=|==|!=|>|<)\s+(?P<value>\S+)"
    r"(?P<mods>(?:\s+(?:window|cooldown|release|agg)\s+\S+)*)\s*$"
)
_MOD_RE = re.compile(r"(window|cooldown|release|agg)\s+(\S+)")


def parse_policy_text(text: str, name: str = "policy") -> Policy:
    """Parse the compact line-oriented front-end into a :class:`Policy`."""
    d: Dict[str, Any] = {"policy": name, "flows": [], "triggers": []}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.split("#", 1)[0].strip()
        if not line:
            continue
        try:
            _parse_text_line(line, d)
        except PolicyError as exc:
            raise PolicyError(f"line {lineno}: {exc}") from None
    return policy_from_dict(d)


def _parse_text_line(line: str, d: Dict[str, Any]) -> None:
    if line.startswith("policy "):
        # policy <name> [stage <stage> [shards <n>]]
        toks = line.split()
        d["policy"] = toks[1]
        if len(toks) >= 4 and toks[2] == "stage":
            d["stage"] = toks[3]
            if len(toks) >= 6 and toks[4] == "shards":
                d["shards"] = toks[5]
        return
    if line.startswith("stage "):
        d["stage"] = line.split(None, 1)[1].strip()
        return
    if line.startswith("shards "):
        d["shards"] = line.split(None, 1)[1].strip()
        return
    if line.startswith("for "):
        head, _, tail = line[4:].partition(":")
        if not tail.strip():
            raise PolicyError(f"'for' statement needs ': <action>': {line!r}")
        toks = head.split()
        alias = None
        if "as" in toks:
            i = toks.index("as")
            if i + 1 >= len(toks):
                raise PolicyError(f"'as' needs a name: {line!r}")
            alias = toks[i + 1]
            toks = toks[:i] + toks[i + 2:]
        # bare 'global' qualifier: the flow spans every registered stage
        scope = "global" if "global" in toks else "stage"
        toks = [t for t in toks if t != "global"]
        match: Dict[str, Any] = {}
        for kv in toks:
            if "=" not in kv:
                raise PolicyError(f"bad match term {kv!r} (need classifier=value)")
            k, v = kv.split("=", 1)
            match[k] = v
        canon = _canon_match(match)
        flow_name = alias or _flow_name_from_match(canon)
        objects = []
        filters = []
        for a_text in tail.split(";"):
            a_text = a_text.strip()
            if not a_text:
                continue
            if a_text.split(None, 1)[0] == "filter":
                filters.append(_parse_text_filter(a_text))
                continue
            act = _parse_text_action(a_text, flow_name)
            if act.op == "set" and (act.flow in (None, flow_name)) and "rate" in act.state_dict():
                # provisioning sugar: a rate limit on the flow's own channel
                # becomes a DRL object, not a runtime enf rule
                objects.append({"kind": "drl", "id": act.object_id, "params": act.state_dict()})
            else:
                raise PolicyError(
                    f"'for' statements only provision their own flow (got {a_text!r}); "
                    "use 'when' for runtime actions"
                )
        flow_d: Dict[str, Any] = {"name": flow_name, "match": dict(canon), "objects": objects}
        if filters:
            flow_d["filters"] = filters
        if scope != "stage":
            flow_d["scope"] = scope
        d["flows"].append(flow_d)
        return
    if line.startswith("when "):
        head, _, tail = line.partition(":")
        if not tail.strip():
            raise PolicyError(f"'when' statement needs ': <action>': {line!r}")
        m = _WHEN_RE.match(head.strip())
        if not m:
            raise PolicyError(
                f"bad 'when' head {head.strip()!r} "
                "(when <metric>[@flow] <op> <value> [window <t>] [cooldown <t>] [release <v>] [agg <a>])"
            )
        when: Dict[str, Any] = {
            "metric": m.group("metric"),
            "op": m.group("op"),
            "value": m.group("value"),
        }
        if m.group("flow"):
            when["flow"] = m.group("flow")
        trig: Dict[str, Any] = {"when": when, "name": f"trigger{len(d['triggers'])}"}
        for mod, val in _MOD_RE.findall(m.group("mods") or ""):
            if mod == "window":
                when["window"] = val
            elif mod == "cooldown":
                trig["cooldown"] = val
            elif mod == "agg":
                when["agg"] = val
            elif mod == "release":
                # release <v>: hysteresis = |value - v| and auto release actions
                trig["hysteresis"] = abs(parse_quantity(when["value"]) - parse_quantity(val))
        actions = [
            _parse_text_action(a.strip(), None) for a in tail.split(";") if a.strip()
        ]
        trig["do"] = [_action_to_dict(a) for a in actions]
        # demote actions auto-pair with promote on release (and vice versa)
        releases = [
            {"op": "promote", "flow": a.flow} for a in actions if a.op == "demote"
        ] + [{"op": "demote", "flow": a.flow} for a in actions if a.op == "promote"]
        if releases:
            trig["release"] = releases
        d["triggers"].append(trig)
        return
    if line.startswith("objective "):
        toks = line.split()
        od: Dict[str, Any] = {"kind": toks[1]}
        i = 2
        while i < len(toks):
            key = toks[i]
            if i + 1 >= len(toks):
                raise PolicyError(f"objective key {key!r} needs a value")
            val = toks[i + 1]
            if key in ("demands", "flows"):
                sub: Dict[str, Any] = {}
                for kv in val.split(","):
                    k, _, v = kv.partition("=")
                    if not v:
                        raise PolicyError(f"bad objective {key} term {kv!r}")
                    sub[k] = v
                od[key] = sub
            else:
                od[key] = val
            i += 2
        d["objective"] = od
        return
    raise PolicyError(f"unrecognized statement: {line!r}")


# --------------------------------------------------------------------------- #
# loading                                                                      #
# --------------------------------------------------------------------------- #
def load_policy(source: Any, name: Optional[str] = None) -> Policy:
    """Parse a policy from whatever the caller has.

    Accepts a :class:`Policy` (returned as-is), a dict (canonical form), a
    path to a ``.json`` / ``.pol`` file, or raw DSL text.
    """
    if isinstance(source, Policy):
        return source
    if isinstance(source, Mapping):
        return policy_from_dict(source)
    text = str(source)
    if "\n" not in text and text.strip().endswith((".json", ".pol", ".policy")):
        return load_policy_file(text.strip())
    stripped = text.lstrip()
    if stripped.startswith("{"):
        return policy_from_dict(json.loads(text))
    return parse_policy_text(text, name=name or "policy")


def load_policy_file(path: str) -> Policy:
    import os

    with open(path) as f:
        text = f.read()
    base = os.path.basename(path).rsplit(".", 1)[0]
    if path.endswith(".json"):
        try:
            return policy_from_dict(json.loads(text))
        except json.JSONDecodeError as exc:
            raise PolicyError(f"{path}: invalid JSON: {exc}") from None
    return parse_policy_text(text, name=base)
