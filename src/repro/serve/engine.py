"""Serving engine: batched prefill + decode with a PAIO stage on the
request path.

Every admitted request flows through the stage with its tenant classifier, so
an SDS control plane can enforce per-tenant token-rate policies (the paper's
§5.2 fair-share scenario applied to serving): each tenant's channel holds a
DRL object whose rate is the tenant's *token budget per second*; Algorithm 2
redistributes leftover budget when tenants go idle.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import RequestType, Stage, build_context, propagate_tenant
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import init_caches
from repro.models.model import ArchConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    tenant: Optional[str] = None


class ServeEngine:
    """Single-host serving: fixed max batch, greedy decoding.

    ``generate`` runs prompts through prefill then step-wise decode; when a
    ``stage`` is given, each generated token consumes tokens from the
    tenant's channel (context-only enforcement — the zero-copy fast path of
    paper §3.4), so token throughput per tenant is shaped by the control
    plane.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_seq: int = 512,
        stage: Optional[Stage] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.stage = stage
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)

    def _enforce(self, tenant: Optional[str], n_tokens: int) -> None:
        if self.stage is None:
            return
        with propagate_tenant(tenant or "default"):
            ctx = build_context(RequestType.get, size=n_tokens)
            self.stage.enforce(ctx, None)

    def generate(
        self,
        prompts: np.ndarray,  # [B, S0] int32
        max_new_tokens: int = 32,
        tenant: Optional[str] = None,
    ) -> List[GenerationResult]:
        b, s0 = prompts.shape
        caches = init_caches(self.cfg, b, self.max_seq, dtype=self.cfg.compute_dtype)
        batch = {
            "tokens": jnp.asarray(prompts, jnp.int32),
            "positions": jnp.broadcast_to(jnp.arange(s0, dtype=jnp.int32), (b, s0)),
        }
        self._enforce(tenant, b * s0)  # prefill cost: prompt tokens
        next_tok, caches = self._prefill(self.params, caches, batch)
        outs = [[int(t)] for t in np.asarray(next_tok)[:, 0]]
        for step in range(1, max_new_tokens):
            pos = jnp.full((b, 1), s0 + step - 1, jnp.int32)
            self._enforce(tenant, b)  # one token per sequence
            next_tok, caches = self._decode(
                self.params, caches, {"tokens": next_tok, "positions": pos}
            )
            for i, t in enumerate(np.asarray(next_tok)[:, 0]):
                outs[i].append(int(t))
        return [GenerationResult(tokens=o, prompt_len=s0, tenant=tenant) for o in outs]
