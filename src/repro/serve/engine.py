"""Serving engine: batched prefill + decode with a PAIO stage on the
request path.

Every admitted request flows through the stage with its tenant classifier, so
an SDS control plane can enforce per-tenant token-rate policies (the paper's
§5.2 fair-share scenario applied to serving): each tenant's channel holds a
DRL object whose rate is the tenant's *token budget per second*; Algorithm 2
redistributes leftover budget when tenants go idle.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import RequestType, Stage, build_context, propagate_tenant
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import init_caches
from repro.models.model import ArchConfig


@dataclasses.dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    tenant: Optional[str] = None


@dataclasses.dataclass
class _Pending:
    """A queued generation request awaiting batch admission."""

    prompts: np.ndarray
    max_new_tokens: int
    tenant: Optional[str]


class ServeEngine:
    """Single-host serving: fixed max batch, greedy decoding.

    ``generate`` runs prompts through prefill then step-wise decode; when a
    ``stage`` is given, each generated token consumes tokens from the
    tenant's channel (context-only enforcement — the zero-copy fast path of
    paper §3.4), so token throughput per tenant is shaped by the control
    plane.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_seq: int = 512,
        stage: Optional[Stage] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.stage = stage
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)
        self._queue: "queue.Queue[_Pending]" = queue.Queue()

    def _enforce(self, tenant: Optional[str], n_tokens: int) -> None:
        if self.stage is None:
            return
        with propagate_tenant(tenant or "default"):
            ctx = build_context(RequestType.get, size=n_tokens)
            self.stage.enforce(ctx, None)

    # -- batched submit path (batched data plane) -------------------------
    def submit(
        self,
        prompts: np.ndarray,
        max_new_tokens: int = 32,
        tenant: Optional[str] = None,
    ) -> None:
        """Queue a generation request; ``drain`` admits and runs the queue."""
        self._queue.put(_Pending(np.asarray(prompts), int(max_new_tokens), tenant))

    def _admit_batch(self, pending: List[_Pending]) -> None:
        """Enforce the queued requests' prefill token cost as ONE batch.

        Each pending request contributes one context carrying its tenant and
        its prompt-token cost; the stage routes and rate-limits the whole drain
        in a single ``enforce_batch`` pass (per-tenant DRLs each see one
        cumulative consume instead of per-request lock/clock traffic).
        """
        if self.stage is None or not pending:
            return
        ctxs = []
        for p in pending:
            b, s0 = p.prompts.shape
            ctxs.append(
                build_context(
                    RequestType.get, size=b * s0, request_context="", workflow_id=None
                )
            )
            ctxs[-1].tenant = p.tenant or "default"
        self.stage.enforce_batch(ctxs)

    def drain(self) -> List[GenerationResult]:
        """Drain the submit queue: batch-admit all queued requests through
        ``Stage.enforce_batch``, then generate each (decode-step token costs
        are still enforced per step, as in ``generate``)."""
        pending: List[_Pending] = []
        while True:
            try:
                pending.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if not pending:
            return []
        self._admit_batch(pending)
        results: List[GenerationResult] = []
        for p in pending:
            results.extend(
                self.generate(
                    p.prompts,
                    max_new_tokens=p.max_new_tokens,
                    tenant=p.tenant,
                    _prefill_admitted=True,
                )
            )
        return results

    def generate(
        self,
        prompts: np.ndarray,  # [B, S0] int32
        max_new_tokens: int = 32,
        tenant: Optional[str] = None,
        _prefill_admitted: bool = False,
    ) -> List[GenerationResult]:
        b, s0 = prompts.shape
        caches = init_caches(self.cfg, b, self.max_seq, dtype=self.cfg.compute_dtype)
        batch = {
            "tokens": jnp.asarray(prompts, jnp.int32),
            "positions": jnp.broadcast_to(jnp.arange(s0, dtype=jnp.int32), (b, s0)),
        }
        if not _prefill_admitted:  # drain() already batch-admitted prefill cost
            self._enforce(tenant, b * s0)  # prefill cost: prompt tokens
        next_tok, caches = self._prefill(self.params, caches, batch)
        outs = [[int(t)] for t in np.asarray(next_tok)[:, 0]]
        for step in range(1, max_new_tokens):
            pos = jnp.full((b, 1), s0 + step - 1, jnp.int32)
            self._enforce(tenant, b)  # one token per sequence
            next_tok, caches = self._decode(
                self.params, caches, {"tokens": next_tok, "positions": pos}
            )
            for i, t in enumerate(np.asarray(next_tok)[:, 0]):
                outs[i].append(int(t))
        return [GenerationResult(tokens=o, prompt_len=s0, tenant=tenant) for o in outs]
