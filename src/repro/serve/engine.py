"""Serving engine: batched prefill + decode with a PAIO stage on the
request path.

Every admitted request flows through the stage with its tenant classifier, so
an SDS control plane can enforce per-tenant token-rate policies (the paper's
§5.2 fair-share scenario applied to serving): each tenant's channel holds a
DRL object whose rate is the tenant's *token budget per second*; Algorithm 2
redistributes leftover budget when tenants go idle.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import RequestType, Stage, build_context, propagate_tenant
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models import init_caches
from repro.models.model import ArchConfig
from repro.telemetry.metrics import MetricRegistry, get_registry


@dataclasses.dataclass
class GenerationResult:
    tokens: List[int]
    prompt_len: int
    tenant: Optional[str] = None


@dataclasses.dataclass
class _Pending:
    """A queued generation request awaiting batch admission."""

    prompts: np.ndarray
    max_new_tokens: int
    tenant: Optional[str]


@dataclasses.dataclass
class _Live:
    """One prefetched generation mid-decode (drain's lockstep loop state)."""

    pending: _Pending
    caches: object
    next_tok: object
    outs: List[List[int]]
    batch: int
    prompt_len: int


class ServeEngine:
    """Single-host serving: fixed max batch, greedy decoding.

    ``generate`` runs prompts through prefill then step-wise decode; when a
    ``stage`` is given, each generated token consumes tokens from the
    tenant's channel (context-only enforcement — the zero-copy fast path of
    paper §3.4), so token throughput per tenant is shaped by the control
    plane.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        max_seq: int = 512,
        stage: Optional[Stage] = None,
        drain_concurrency: int = 4,
        registry: Optional[MetricRegistry] = None,
    ) -> None:
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.stage = stage
        #: serve statistics publish into the shared process-wide registry by
        #: default — one exporter endpoint covers serving and storage planes;
        #: pass an explicit registry for isolation
        self._registry = registry if registry is not None else get_registry()
        self._described: set = set()
        #: lockstep window of ``drain``: how many queued requests decode (and
        #: hold KV caches) simultaneously. Peak drain memory is roughly
        #: ``drain_concurrency × init_caches(cfg, b, max_seq)`` — size it to
        #: the deployment; 1 restores the sequential (one-cache) envelope at
        #: the cost of per-window decode-enforcement coalescing.
        self.drain_concurrency = int(drain_concurrency)
        self._prefill = jax.jit(build_prefill_step(cfg))
        self._decode = jax.jit(build_decode_step(cfg), donate_argnums=1)
        self._queue: "queue.Queue[_Pending]" = queue.Queue()

    def _describe_once(self, key: str, family: str, labels=None) -> None:
        # descriptors are immutable per key: describe once, not per decode
        # step (the registry lock + labels dict per call is avoidable churn)
        if key not in self._described:
            self._registry.describe(key, family, labels)
            self._described.add(key)

    def _publish(self, tenant: Optional[str], n_tokens: int, elapsed_s: float) -> None:
        """One completed generation's telemetry: per-tenant token counter plus
        a windowed generation-latency summary (p50/p95/p99 on the exporter).
        ``elapsed_s`` is the wall time the request experienced end to end —
        drain() passes its lockstep window's full duration for every request
        in the window (they all finish when the window does), so the summary
        means the same thing for queued and direct generations."""
        tenant = tenant or "default"
        key = f"serve.{tenant}.tokens"
        self._registry.inc(key, float(n_tokens))
        self._describe_once(key, "paio_serve_tokens", {"tenant": tenant})
        self._registry.observe("serve.generate_ms", elapsed_s * 1e3)
        self._describe_once("serve.generate_ms", "paio_serve_generate_ms")

    def _publish_step(self, elapsed_s: float) -> None:
        """One decode step's wall time (drain: the lockstep step across all
        live requests; generate: the single request's step)."""
        self._registry.observe("serve.decode_step_ms", elapsed_s * 1e3)
        self._describe_once("serve.decode_step_ms", "paio_serve_decode_step_ms")

    def _enforce(self, tenant: Optional[str], n_tokens: int) -> None:
        if self.stage is None:
            return
        with propagate_tenant(tenant or "default"):
            ctx = build_context(RequestType.get, size=n_tokens)
            self.stage.enforce(ctx, None)

    # -- batched submit path (batched data plane) -------------------------
    def submit(
        self,
        prompts: np.ndarray,
        max_new_tokens: int = 32,
        tenant: Optional[str] = None,
    ) -> None:
        """Queue a generation request; ``drain`` admits and runs the queue."""
        self._queue.put(_Pending(np.asarray(prompts), int(max_new_tokens), tenant))

    def _admit_batch(self, pending: List[_Pending]) -> None:
        """Enforce the queued requests' prefill token cost as ONE batch.

        Each pending request contributes one context carrying its tenant and
        its prompt-token cost; the stage routes and rate-limits the whole drain
        in a single ``enforce_batch`` pass (per-tenant DRLs each see one
        cumulative consume instead of per-request lock/clock traffic).
        """
        if self.stage is None or not pending:
            return
        ctxs = []
        for p in pending:
            b, s0 = p.prompts.shape
            ctxs.append(
                build_context(
                    RequestType.get, size=b * s0, request_context="", workflow_id=None
                )
            )
            ctxs[-1].tenant = p.tenant or "default"
        self.stage.enforce_batch(ctxs)

    def _enforce_step_batch(self, lives: List[_Live]) -> None:
        """Coalesce one decode step's token costs across all live requests
        into ONE ``enforce_batch`` pass: each live request contributes a
        context carrying its tenant and its per-step cost (one token per
        sequence), exactly what ``generate`` enforces per step — but the
        stage routes/rate-limits the whole step at batch cost."""
        if self.stage is None or not lives:
            return
        ctxs = []
        for lv in lives:
            ctx = build_context(
                RequestType.get, size=lv.batch, request_context="", workflow_id=None
            )
            ctx.tenant = lv.pending.tenant or "default"
            ctxs.append(ctx)
        self.stage.enforce_batch(ctxs)

    def _prefill_one(self, p: _Pending) -> _Live:
        b, s0 = p.prompts.shape
        caches = init_caches(self.cfg, b, self.max_seq, dtype=self.cfg.compute_dtype)
        batch = {
            "tokens": jnp.asarray(p.prompts, jnp.int32),
            "positions": jnp.broadcast_to(jnp.arange(s0, dtype=jnp.int32), (b, s0)),
        }
        next_tok, caches = self._prefill(self.params, caches, batch)
        outs = [[int(t)] for t in np.asarray(next_tok)[:, 0]]
        return _Live(p, caches, next_tok, outs, b, s0)

    def _decode_one_step(self, lv: _Live, step: int) -> None:
        pos = jnp.full((lv.batch, 1), lv.prompt_len + step - 1, jnp.int32)
        lv.next_tok, lv.caches = self._decode(
            self.params, lv.caches, {"tokens": lv.next_tok, "positions": pos}
        )
        for i, t in enumerate(np.asarray(lv.next_tok)[:, 0]):
            lv.outs[i].append(int(t))

    def drain(self, max_concurrent: Optional[int] = None) -> List[GenerationResult]:
        """Drain the submit queue with batched enforcement end to end.

        Prefill admission for all queued requests is ONE ``enforce_batch``
        call (``_admit_batch``); the decode loops then run in lockstep so each
        decode *step* enforces its token costs across all live requests in one
        ``enforce_batch`` pass instead of one ``enforce`` per request per
        step. Token accounting per tenant is identical to sequential
        ``generate`` calls; only the lock/route/dispatch cost is amortized.

        Every lockstepped request holds its KV caches live simultaneously, so
        the queue is processed in windows of ``max_concurrent`` requests
        (default: the engine's ``drain_concurrency``) — memory is bounded by
        the window, not the (unbounded) queue depth.
        """
        window_size = max(
            self.drain_concurrency if max_concurrent is None else max_concurrent, 1
        )
        pending: List[_Pending] = []
        while True:
            try:
                pending.append(self._queue.get_nowait())
            except queue.Empty:
                break
        if not pending:
            return []
        self._admit_batch(pending)
        results: List[GenerationResult] = []
        t0 = time.monotonic()  # queue wait across earlier windows counts too
        for at in range(0, len(pending), window_size):
            window = pending[at : at + window_size]
            lives = [self._prefill_one(p) for p in window]
            step = 1
            while True:
                active = [lv for lv in lives if step < lv.pending.max_new_tokens]
                if not active:
                    break
                ts = time.monotonic()
                self._enforce_step_batch(active)
                for lv in active:
                    self._decode_one_step(lv, step)
                self._publish_step(time.monotonic() - ts)
                step += 1
            elapsed = time.monotonic() - t0
            for lv in lives:
                # each request experiences its window's duration PLUS the
                # time spent queued behind earlier windows of this drain —
                # publish that full span, not a per-request split
                self._publish(lv.pending.tenant, sum(len(o) for o in lv.outs), elapsed)
                results.extend(
                    GenerationResult(tokens=o, prompt_len=lv.prompt_len, tenant=lv.pending.tenant)
                    for o in lv.outs
                )
        return results

    def generate(
        self,
        prompts: np.ndarray,  # [B, S0] int32
        max_new_tokens: int = 32,
        tenant: Optional[str] = None,
        _prefill_admitted: bool = False,
    ) -> List[GenerationResult]:
        prompts = np.asarray(prompts)
        b, s0 = prompts.shape
        t0 = time.monotonic()
        if not _prefill_admitted:  # drain() already batch-admitted prefill cost
            self._enforce(tenant, b * s0)  # prefill cost: prompt tokens
        lv = self._prefill_one(_Pending(prompts, int(max_new_tokens), tenant))
        for step in range(1, max_new_tokens):
            ts = time.monotonic()
            self._enforce(tenant, b)  # one token per sequence
            self._decode_one_step(lv, step)
            self._publish_step(time.monotonic() - ts)
        self._publish(tenant, sum(len(o) for o in lv.outs), time.monotonic() - t0)
        return [GenerationResult(tokens=o, prompt_len=s0, tenant=tenant) for o in lv.outs]
