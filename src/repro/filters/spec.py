"""FilterSpec: the wire-level identity of one filter installation.

A spec names *what* to instantiate (a registered filter ``name`` at a pinned
``version`` with constructor ``params``) and *where* to place it (a
``channel`` of the target stage; ``filter_id`` is the instance slot on that
channel, so the same filter class can be installed twice under different
ids). Placement by *flow* is a DSL-level concept — the policy compiler
resolves a flow to its channel before the spec ever reaches the wire.

Specs ship over the control plane as housekeeping rules (``install_filter``
/ ``remove_filter`` ops), which buys the whole rule machinery for free:
v1 JSON fallback via ``to_wire``, deferred replay for down stages, shard
fan-out, and crash-safe journaling through ``StageConfigJournal``. The v2
binary transport additionally carries install rules on a dedicated
struct-packed codec entry (``repro.transport.codec.encode_filter_spec``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from repro.core.rules import HousekeepingRule

__all__ = ["FilterSpec", "INSTALL_FILTER", "REMOVE_FILTER", "FILTER_OPS"]

#: housekeeping ops of the filter-install plane
INSTALL_FILTER = "install_filter"
REMOVE_FILTER = "remove_filter"
FILTER_OPS = (INSTALL_FILTER, REMOVE_FILTER)

#: version sentinel: "latest registered version at install time"
LATEST = 0


@dataclass(frozen=True)
class FilterSpec:
    """One filter installation: registry identity + placement.

    ``version`` 0 means "latest registered on the installing stage" — the
    policy compiler pins a concrete version when the target stage advertises
    its registry, so 0 only survives to the wire for offline-compiled
    programs.
    """

    name: str
    version: int = LATEST
    channel: str = ""
    filter_id: str = ""
    params: Dict[str, Any] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if not self.filter_id:
            object.__setattr__(self, "filter_id", self.name)

    # -- rule plumbing -----------------------------------------------------
    def to_rule(self) -> HousekeepingRule:
        """The ``install_filter`` housekeeping rule shipping this spec."""
        return HousekeepingRule(
            op=INSTALL_FILTER,
            channel=self.channel,
            object_id=self.filter_id,
            object_kind=self.name,
            params={"version": int(self.version), "params": dict(self.params)},
        )

    def removal_rule(self) -> HousekeepingRule:
        return HousekeepingRule(
            op=REMOVE_FILTER, channel=self.channel, object_id=self.filter_id
        )

    @classmethod
    def from_rule(cls, rule: HousekeepingRule) -> "FilterSpec":
        if rule.op != INSTALL_FILTER:
            raise ValueError(f"not an install_filter rule: {rule.op!r}")
        params = rule.params or {}
        return cls(
            name=rule.object_kind or "",
            version=int(params.get("version") or LATEST),
            channel=rule.channel,
            filter_id=rule.object_id or (rule.object_kind or ""),
            params=dict(params.get("params") or {}),
        )

    # -- JSON-native form (describe / stage_info) --------------------------
    def to_wire(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "version": self.version,
            "channel": self.channel,
            "filter_id": self.filter_id,
            "params": dict(self.params),
        }

    @classmethod
    def from_wire(cls, d: Dict[str, Any]) -> "FilterSpec":
        return cls(
            name=d["name"],
            version=int(d.get("version") or LATEST),
            channel=d.get("channel") or "",
            filter_id=d.get("filter_id") or "",
            params=dict(d.get("params") or {}),
        )
