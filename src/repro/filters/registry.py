"""Process-wide registry of named, versioned filter classes.

A *filter* is an enforcement object (same ``obj_enf`` / ``obj_enf_batch`` /
``obj_config`` protocol) that wraps a channel's object dispatch instead of
occupying an object slot: installed filters post-process every enforced
request's result, in install order. This is Crystal's injectable-filter
abstraction grafted onto PAIO's stage anatomy — new data-plane logic deploys
at runtime, no stage restart.

Two extensions over plain enforcement objects:

* ``observe(ctx, wait_seconds)`` — called once per enforced request with the
  scheduling delay the channel's enforcement objects imposed, so sampling /
  tracing filters can watch latency without sitting in the wait path;
* ``collect_extras()`` — windowed, *summable* counters drained by the
  channel's ``collect`` into ``StatsSnapshot.extras``, which is how filter
  metrics (cache hit counts, compressed bytes) reach the control-plane
  trigger engine and the Prometheus exporter.

The registry maps ``name -> {version -> class}``. Stages advertise its
contents through ``stage_info()["filters"]`` so the policy compiler and the
offline verifier can validate a ``filters:`` stanza (names, versions, param
names) before anything ships.
"""
from __future__ import annotations

import inspect
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

from repro.core.clock import Clock
from repro.core.context import Context
from repro.core.objects import EnforcementObject, Result

__all__ = ["Filter", "FilterError", "FilterRegistry", "FILTER_REGISTRY", "register_filter"]


class FilterError(ValueError):
    """Unknown filter name/version, or params the filter does not accept."""


class Filter(EnforcementObject):
    """Base class for runtime-installable filters.

    Subclasses set ``name`` (registry identity) and ``version``, implement
    the enforcement-object protocol, and may override ``observe`` /
    ``collect_extras``. ``obj_enf`` receives the *result content* of the
    channel's enforcement object and returns the (possibly transformed)
    content onward — filters chain.
    """

    kind = "filter"
    #: registry identity; subclasses must override
    name: str = "abstract"
    #: monotonically bumped when behaviour or params change incompatibly
    version: int = 1

    def obj_enf(self, ctx: Context, request: Any = None) -> Result:
        return Result(content=request)

    def obj_config(self, state: Dict[str, Any]) -> None:
        pass

    def observe(self, ctx: Context, wait_seconds: float) -> None:
        """Per-request hook: the wait the channel's objects imposed."""

    def collect_extras(self) -> Dict[str, float]:
        """Drain this window's summable counters (reset on read)."""
        return {}

    def describe(self) -> Dict[str, Any]:
        return {"kind": self.kind, "name": self.name, "version": self.version}


def _param_names(cls: Type[Filter]) -> Tuple[str, ...]:
    """Constructor keyword names (minus self/clock) — the param schema a
    stage advertises and the compiler/verifier validate against."""
    try:
        sig = inspect.signature(cls.__init__)
    except (TypeError, ValueError):  # builtins without introspectable sigs
        return ()
    return tuple(
        p.name
        for p in sig.parameters.values()
        if p.name not in ("self", "clock")
        and p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)
    )


class FilterRegistry:
    """Thread-safe ``name -> {version -> class}`` registry."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._classes: Dict[str, Dict[int, Type[Filter]]] = {}

    def register(
        self,
        cls: Type[Filter],
        name: Optional[str] = None,
        version: Optional[int] = None,
    ) -> Type[Filter]:
        name = name or cls.name
        version = int(version if version is not None else cls.version)
        if not name or name == "abstract":
            raise FilterError(f"filter class {cls.__name__} has no registry name")
        if version < 1:
            raise FilterError(f"filter {name!r}: version must be >= 1, got {version}")
        with self._lock:
            versions = self._classes.setdefault(name, {})
            prior = versions.get(version)
            if prior is not None and prior is not cls:
                # a versioned slot is immutable: silently replacing it would
                # change what peers get for an already-advertised (name,
                # version) — ship the new code as a new version instead
                raise FilterError(
                    f"filter {name!r} version {version} is already registered "
                    f"({prior.__name__}); bump the version to ship new code"
                )
            versions[version] = cls
        return cls

    def names(self) -> Tuple[str, ...]:
        with self._lock:
            return tuple(sorted(self._classes))

    def versions(self, name: str) -> Tuple[int, ...]:
        with self._lock:
            return tuple(sorted(self._classes.get(name, ())))

    def latest(self, name: str) -> int:
        versions = self.versions(name)
        if not versions:
            raise FilterError(f"unknown filter {name!r} (registered: {list(self.names())})")
        return versions[-1]

    def lookup(self, name: str, version: int = 0) -> Type[Filter]:
        """Resolve a class; version 0 = latest registered."""
        with self._lock:
            by_version = self._classes.get(name)
            if not by_version:
                known = sorted(self._classes)
                raise FilterError(f"unknown filter {name!r} (registered: {known})")
            if not version:
                return by_version[max(by_version)]
            cls = by_version.get(int(version))
            if cls is None:
                raise FilterError(
                    f"filter {name!r} has no version {version} "
                    f"(registered: {sorted(by_version)})"
                )
            return cls

    def param_names(self, name: str, version: int = 0) -> Tuple[str, ...]:
        return _param_names(self.lookup(name, version))

    def create(
        self,
        name: str,
        version: int = 0,
        params: Optional[Dict[str, Any]] = None,
        clock: Optional[Clock] = None,
    ) -> Filter:
        """Instantiate; raises :class:`FilterError` on unknown name/version
        or params the constructor does not accept."""
        cls = self.lookup(name, version)
        params = dict(params or {})
        allowed = set(_param_names(cls))
        unknown = sorted(set(params) - allowed)
        if unknown:
            raise FilterError(
                f"filter {name!r} v{version or self.latest(name)}: unknown "
                f"params {unknown} (accepts: {sorted(allowed)})"
            )
        try:
            sig = inspect.signature(cls.__init__)
        except (TypeError, ValueError):
            sig = None
        if clock is not None and sig is not None and "clock" in sig.parameters:
            params["clock"] = clock
        try:
            return cls(**params)
        except (TypeError, ValueError) as exc:
            raise FilterError(f"filter {name!r}: {exc}") from exc

    def advertise(self) -> Dict[str, Any]:
        """The registry contents a stage puts in ``stage_info()["filters"]``:
        per name, the registered versions and the latest version's param
        names — everything the compiler needs to validate a spec remotely."""
        out: Dict[str, Any] = {}
        with self._lock:
            items = {n: dict(v) for n, v in self._classes.items()}
        for name, by_version in sorted(items.items()):
            latest = max(by_version)
            out[name] = {
                "versions": sorted(by_version),
                "latest": latest,
                "params": list(_param_names(by_version[latest])),
            }
        return out


#: the process-wide registry; builtin filters register on import of
#: :mod:`repro.filters`
FILTER_REGISTRY = FilterRegistry()


def register_filter(cls: Type[Filter]) -> Type[Filter]:
    """Class decorator: register into the process-wide registry."""
    return FILTER_REGISTRY.register(cls)
