"""Runtime-pluggable enforcement filters (ROADMAP Open Item 2).

PAIO's enforcement-object set is fixed at build time; this subsystem makes
the *logic* pluggable at runtime, Crystal-style: a process-wide
:class:`FilterRegistry` of named, versioned filter classes, a wire-level
:class:`FilterSpec` shipped over the control plane as housekeeping rules,
and three shipping filters (compression, content cache, trace sampler).

Importing this package registers the builtin filters.
"""
from .builtin import CompressionFilter, ContentCacheFilter, TraceFilter
from .registry import FILTER_REGISTRY, Filter, FilterError, FilterRegistry, register_filter
from .spec import FILTER_OPS, INSTALL_FILTER, REMOVE_FILTER, FilterSpec

__all__ = [
    "CompressionFilter",
    "ContentCacheFilter",
    "TraceFilter",
    "FILTER_REGISTRY",
    "Filter",
    "FilterError",
    "FilterRegistry",
    "register_filter",
    "FILTER_OPS",
    "INSTALL_FILTER",
    "REMOVE_FILTER",
    "FilterSpec",
]
