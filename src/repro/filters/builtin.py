"""The three shipping filters: compression, content cache, trace sampler.

Each is deliberately small — the point of the subsystem is that logic like
this installs onto a *running* stage through the control plane, so every
filter here doubles as a reference implementation of the protocol:

* transform content in ``obj_enf`` / ``obj_enf_batch``,
* keep windowed **summable** counters and drain them in ``collect_extras``
  (ratios are derived control-plane side from merged raw counts),
* never raise on missing optional dependencies: a filter install must
  succeed on any stage, so :class:`CompressionFilter` gates ``zstandard``
  and falls back to a numpy byte-shuffle + DEFLATE pipeline.
"""
from __future__ import annotations

import threading
import zlib
from bisect import bisect_left
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.context import Context
from repro.core.objects import Result
from repro.telemetry.histogram import NBUCKETS, WAIT_BOUNDS_MS

from .registry import Filter, register_filter

__all__ = ["CompressionFilter", "ContentCacheFilter", "TraceFilter"]

#: extras key prefix carrying the trace filter's sparse wait histogram
#: (bucket index appended); summable across windows/stages like every extra
TRACE_HIST_PREFIX = "trace.wait_hist."


def _as_bytes(request: Any) -> bytes:
    if isinstance(request, np.ndarray):
        return request.tobytes()
    return bytes(request)


@register_filter
class CompressionFilter(Filter):
    """zstd compression for cold tenants; byte-shuffle + DEFLATE fallback.

    Unlike the build-time ``compress`` enforcement object (which *requires*
    ``zstandard`` at construction), an installable filter must come up on
    whatever stage it lands on: when ``zstandard`` is absent the filter
    byte-shuffles the payload with numpy (byte plane *i* of every 8-byte
    word grouped together — similar-magnitude values line up, which is what
    makes DEFLATE competitive on numeric data) and compresses with zlib.

    Extras: ``compress.raw_bytes`` / ``compress.out_bytes`` per window — the
    fleet-merged ratio is derived control-plane side.
    """

    name = "compression"
    version = 1

    _SHUFFLE_WORD = 8  # byte planes per word for the fallback shuffle

    def __init__(self, level: int = 3) -> None:
        self.level = int(level)
        self._lock = threading.Lock()
        self._raw = 0
        self._out = 0
        try:
            import zstandard
        except ImportError:
            zstandard = None
        self._zstd = zstandard
        self._cctx = (
            zstandard.ZstdCompressor(level=self.level) if zstandard is not None else None
        )
        self.backend = "zstd" if zstandard is not None else "shuffle+zlib"

    def _compress(self, buf: bytes) -> bytes:
        if self._cctx is not None:
            return self._cctx.compress(buf)
        arr = np.frombuffer(buf, dtype=np.uint8)
        pad = (-arr.size) % self._SHUFFLE_WORD
        if pad:
            arr = np.concatenate([arr, np.zeros(pad, np.uint8)])
        shuffled = arr.reshape(-1, self._SHUFFLE_WORD).T.tobytes()
        return zlib.compress(shuffled, min(max(self.level, 1), 9))

    def obj_enf(self, ctx: Context, request: Any = None) -> Result:
        if request is None:
            return Result(content=None)
        buf = _as_bytes(request)
        out = self._compress(buf)
        with self._lock:
            self._raw += len(buf)
            self._out += len(out)
        return Result(
            content=out,
            meta={"raw_bytes": len(buf), "compressed_bytes": len(out), "codec": self.backend},
        )

    def obj_enf_batch(
        self, ctxs: Sequence[Context], requests: Optional[Sequence[Any]] = None
    ) -> List[Result]:
        if requests is None:
            return [Result() for _ in ctxs]
        out: List[Result] = []
        raw_total = out_total = 0
        for ctx, r in zip(ctxs, requests):
            if r is None:
                out.append(Result())
                continue
            buf = _as_bytes(r)
            comp = self._compress(buf)
            raw_total += len(buf)
            out_total += len(comp)
            out.append(
                Result(
                    content=comp,
                    meta={
                        "raw_bytes": len(buf),
                        "compressed_bytes": len(comp),
                        "codec": self.backend,
                    },
                )
            )
        if raw_total:
            with self._lock:
                self._raw += raw_total
                self._out += out_total
        return out

    def obj_config(self, state: Dict[str, Any]) -> None:
        if "level" in state:
            self.level = int(state["level"])
            if self._zstd is not None:
                self._cctx = self._zstd.ZstdCompressor(level=self.level)

    def collect_extras(self) -> Dict[str, float]:
        with self._lock:
            raw, self._raw = self._raw, 0
            out, self._out = self._out, 0
        if not raw:
            return {}
        return {"compress.raw_bytes": float(raw), "compress.out_bytes": float(out)}

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(level=self.level, backend=self.backend)
        return d


@register_filter
class ContentCacheFilter(Filter):
    """Content-addressed dedup cache: counts re-seen payloads.

    An LRU of payload digests. A request whose content digest was seen
    recently is a *hit* (the workload is re-reading data a real cache would
    serve); unseen payloads are misses and enter the LRU, evicting the
    oldest entry at capacity. Payloads pass through untouched — the filter
    is a sensor, and its window counters (``cache.hits`` / ``cache.misses``
    / ``cache.evictions``) are what feed the trigger engine: the runtime
    derives ``cache.hit_rate`` from the merged counts, so
    ``when cache.hit_rate@flow < 0.3: demote flow`` works fleet-wide.
    """

    name = "content_cache"
    version = 1

    def __init__(self, capacity: int = 256) -> None:
        if int(capacity) < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._lru: "OrderedDict[int, bool]" = OrderedDict()
        self._hits = 0
        self._misses = 0
        self._evictions = 0

    def _touch(self, key: int) -> bool:
        """True on hit. Caller holds no lock."""
        with self._lock:
            if key in self._lru:
                self._lru.move_to_end(key)
                self._hits += 1
                return True
            self._misses += 1
            self._lru[key] = True
            if len(self._lru) > self.capacity:
                self._lru.popitem(last=False)
                self._evictions += 1
            return False

    def obj_enf(self, ctx: Context, request: Any = None) -> Result:
        if request is None:
            return Result(content=None)
        buf = _as_bytes(request)
        hit = self._touch(zlib.crc32(buf))
        return Result(content=request, meta={"cache": "hit" if hit else "miss"})

    def obj_config(self, state: Dict[str, Any]) -> None:
        if "capacity" in state:
            capacity = int(state["capacity"])
            if capacity < 1:
                raise ValueError(f"capacity must be >= 1, got {capacity}")
            with self._lock:
                self.capacity = capacity
                while len(self._lru) > capacity:
                    self._lru.popitem(last=False)
                    self._evictions += 1

    def collect_extras(self) -> Dict[str, float]:
        with self._lock:
            hits, self._hits = self._hits, 0
            misses, self._misses = self._misses, 0
            evictions, self._evictions = self._evictions, 0
        if not (hits or misses or evictions):
            return {}
        return {
            "cache.hits": float(hits),
            "cache.misses": float(misses),
            "cache.evictions": float(evictions),
        }

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        with self._lock:
            d.update(capacity=self.capacity, entries=len(self._lru))
        return d


@register_filter
class TraceFilter(Filter):
    """Sampling tracer: per-request wait observations into the histogram
    plane.

    Every ``sample_every``-th enforced request contributes its imposed wait
    to a fixed-bucket histogram on the shared ``WAIT_BOUNDS_MS`` layout —
    the same bucket scheme the channel stats use, so sampled-trace
    percentiles and full-population percentiles are directly comparable.
    The buckets drain through extras as sparse ``trace.wait_hist.<i>``
    counts (summable, so shard/fleet merges are exact); the policy runtime
    folds the merged counts back into ``trace.wait_p50/p95/p99_ms`` gauges.
    """

    name = "trace"
    version = 1

    def __init__(self, sample_every: int = 1) -> None:
        if int(sample_every) < 1:
            raise ValueError(f"sample_every must be >= 1, got {sample_every}")
        self.sample_every = int(sample_every)
        self._lock = threading.Lock()
        self._seen = 0
        self._sampled = 0
        self._hist = [0] * NBUCKETS

    def obj_enf(self, ctx: Context, request: Any = None) -> Result:
        return Result(content=request)

    def obj_enf_batch(
        self, ctxs: Sequence[Context], requests: Optional[Sequence[Any]] = None
    ) -> List[Result]:
        if requests is None:
            return [Result() for _ in ctxs]
        return [Result(content=r) for r in requests]

    def observe(self, ctx: Context, wait_seconds: float) -> None:
        idx = bisect_left(WAIT_BOUNDS_MS, wait_seconds * 1e3)
        with self._lock:
            self._seen += 1
            if self._seen % self.sample_every:
                return
            self._sampled += 1
            self._hist[idx] += 1

    def obj_config(self, state: Dict[str, Any]) -> None:
        if "sample_every" in state:
            sample_every = int(state["sample_every"])
            if sample_every < 1:
                raise ValueError(f"sample_every must be >= 1, got {sample_every}")
            self.sample_every = sample_every

    def collect_extras(self) -> Dict[str, float]:
        with self._lock:
            sampled, self._sampled = self._sampled, 0
            hist, self._hist = self._hist, [0] * NBUCKETS
        if not sampled:
            return {}
        out: Dict[str, float] = {"trace.sampled": float(sampled)}
        for i, c in enumerate(hist):
            if c:
                out[f"{TRACE_HIST_PREFIX}{i}"] = float(c)
        return out

    def describe(self) -> Dict[str, Any]:
        d = super().describe()
        d.update(sample_every=self.sample_every)
        return d
