"""Input pipeline with a PAIO stage on the read path.

Every shard read flows through an :class:`ArrayInstance` with the ``fg_fetch``
request context (the *foreground flow* of the training job's I/O stack —
paper §5 mapping). The control plane observes the pipeline's bandwidth via the
stage's statistics and allocates leftover bandwidth to background flows
(checkpoints, eval) — PAIO's tail-latency policy applied to training.

The pipeline prefetches on a background thread into a bounded queue so the
device never blocks on storage unless the storage is genuinely saturated —
which is exactly the condition the control plane reacts to.
"""
from __future__ import annotations

import os
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import FG_FETCH, ArrayInstance, RequestType, Stage, propagate_context
from repro.models.model import ArchConfig


class SyntheticTokenSource:
    """Deterministic synthetic tokens (seeded per batch index).

    Tokens follow a Zipf-like unigram distribution so a model has learnable
    structure (loss drops from ln(V) toward the source entropy) — uniform
    noise would make smoke-training flat.
    """

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0) -> None:
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        ranks = np.arange(vocab, dtype=np.float64)
        p = 1.0 / (ranks + 5.0)
        self._p = p / p.sum()

    def read(self, index: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed + index)
        flat = rng.choice(self.vocab, size=self.batch * self.seq, p=self._p)
        return flat.reshape(self.batch, self.seq).astype(np.int32)

    @property
    def nbytes_per_batch(self) -> int:
        return self.batch * self.seq * 4


#: request context carried by dataset-preparation shard writes (free-form
#: context string, paper §3.3 — lets a policy throttle shard prep against
#: the foreground fetch flow)
DATA_PREP = "bg_data_prep"


class FileTokenSource:
    """Memory-mapped token shards on disk (one flat int32 stream per shard)."""

    def __init__(self, paths: list[str], batch: int, seq: int) -> None:
        self.paths = list(paths)
        self.batch, self.seq = batch, seq
        self._maps = [np.memmap(p, dtype=np.int32, mode="r") for p in self.paths]
        self._sizes = [m.shape[0] for m in self._maps]

    @staticmethod
    def write_shard(path: str, tokens: np.ndarray) -> None:
        arr = np.asarray(tokens, np.int32)
        with open(path, "wb") as f:
            f.write(arr.tobytes())
            f.flush()
            os.fsync(f.fileno())

    @staticmethod
    def write_shards(
        paths: list[str],
        token_arrays: list[np.ndarray],
        stage: Optional[Stage] = None,
        channel_context: str = DATA_PREP,
    ) -> None:
        """Write a shard set through the Instance batch submit API.

        With a stage attached, all shard writes are admitted as ONE
        ``enforce_batch`` pass (per-write routing/stats/rate-limit cost paid
        once per burst) under the ``bg_data_prep`` request context, so a
        control-plane policy can cap shard preparation against foreground
        fetches. Without a stage this is a plain loop over ``write_shard``.
        """
        if len(paths) != len(token_arrays):
            raise ValueError(f"{len(paths)} paths vs {len(token_arrays)} arrays")
        arrays = [np.asarray(t, np.int32) for t in token_arrays]
        if stage is None:
            for path, arr in zip(paths, arrays):
                FileTokenSource.write_shard(path, arr)
            return
        instance = ArrayInstance(stage)
        with propagate_context(channel_context):
            instance.on_write_batch(
                arrays, lambda i, payload: FileTokenSource.write_shard(paths[i], payload)
            )

    def read(self, index: int) -> np.ndarray:
        need = self.batch * self.seq
        shard = self._maps[index % len(self._maps)]
        n_windows = max(shard.shape[0] - need, 1)
        off = (index * 9973) % n_windows
        return np.array(shard[off : off + need]).reshape(self.batch, self.seq)

    @property
    def nbytes_per_batch(self) -> int:
        return self.batch * self.seq * 4


class DataPipeline:
    """Prefetching loader; reads are enforced by the given PAIO stage."""

    def __init__(
        self,
        source,
        stage: Optional[Stage] = None,
        prefetch: int = 2,
        channel_context: str = FG_FETCH,
    ) -> None:
        self.source = source
        self.instance = ArrayInstance(stage) if stage is not None else None
        self.channel_context = channel_context
        self._queue: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._index = 0
        self._thread: Optional[threading.Thread] = None

    # -- synchronous read (used by tests and the quickstart) ---------------
    def read_batch(self, index: int) -> np.ndarray:
        if self.instance is None:
            return self.source.read(index)
        with propagate_context(self.channel_context):
            return self.instance.on_read(self.source.nbytes_per_batch, lambda: self.source.read(index))

    # -- background prefetch ------------------------------------------------
    def start(self) -> "DataPipeline":
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop, daemon=True, name="paio-data-pipeline")
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.is_set():
            batch = self.read_batch(self._index)
            self._index += 1
            while not self._stop.is_set():
                try:
                    self._queue.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def __iter__(self) -> Iterator[np.ndarray]:
        return self

    def __next__(self) -> np.ndarray:
        if self._thread is None:
            batch = self.read_batch(self._index)
            self._index += 1
            return batch
        return self._queue.get()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            while not self._queue.empty():  # unblock producer
                try:
                    self._queue.get_nowait()
                except queue.Empty:
                    break
            self._thread.join(timeout=5.0)
            self._thread = None


# --------------------------------------------------------------------------- #
# batch specs per (arch × shape cell) — shared by dry-run and training         #
# --------------------------------------------------------------------------- #
def make_batch_specs(cfg: ArchConfig, batch: int, seq: int, kind: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of one step.

    ``kind``: ``train`` (full-seq batch), ``prefill`` (full-seq serve),
    ``decode`` (one token against a ``seq``-long cache — token specs only;
    cache specs come from ``models.init_caches`` via ``eval_shape``).
    """
    f32, i32 = jnp.float32, jnp.int32
    s_tok = 1 if kind == "decode" else seq
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "audio":
        specs["frames"] = jax.ShapeDtypeStruct((batch, s_tok, cfg.frontend_dim), f32)
        if kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((batch, s_tok), i32)
        return specs
    if cfg.family == "vlm":
        if kind != "decode":
            specs["vision_embeds"] = jax.ShapeDtypeStruct((batch, cfg.n_vision_tokens, cfg.d_model), f32)
            s_tok = max(s_tok - cfg.n_vision_tokens, 1)  # total seq budget includes vision tokens
    specs["tokens"] = jax.ShapeDtypeStruct((batch, s_tok), i32)
    if kind == "decode":
        specs["positions"] = jax.ShapeDtypeStruct((batch, 1), i32)
    return specs
