from .pipeline import DataPipeline, FileTokenSource, SyntheticTokenSource, make_batch_specs

__all__ = ["DataPipeline", "FileTokenSource", "SyntheticTokenSource", "make_batch_specs"]
