"""End-to-end driver: train a ~100M-parameter LM with the full stack —
PAIO-instrumented pipeline, async DRL-limited checkpoints, TrainIOControl
feedback loop, cosine LR, resume-from-checkpoint.

Presets:
  --preset cpu   ~10M params, 40 steps  — runs on this CPU container (~min)
  --preset 100m  ~100M params, 300 steps — the assignment's e2e shape; run it
                 on real hardware (or be patient)

Run: PYTHONPATH=src python examples/train_lm_100m.py --preset cpu
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch.train import train
from repro.models.model import ArchConfig
import repro.configs.llama3_2_1b as llama


def preset_config(name: str) -> tuple:
    if name == "cpu":
        cfg = llama.config().replace(
            name="lm-10m", n_layers=4, d_model=256, n_heads=8, n_kv_heads=4, d_ff=1024, vocab=8192
        )
        return cfg, dict(steps=40, batch=8, seq=128, lr=1e-3, ckpt_every=20)
    if name == "100m":
        cfg = llama.config().replace(
            name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000
        )
        return cfg, dict(steps=300, batch=32, seq=512, lr=6e-4, ckpt_every=100)
    raise SystemExit(f"unknown preset {name}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=["cpu", "100m"])
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg, run_kw = preset_config(args.preset)
    if args.steps:
        run_kw["steps"] = args.steps

    # register the preset so launch.train can resolve it
    import repro.configs as configs

    module_name = f"repro.configs.{cfg.name.replace('-', '_')}"
    import types

    mod = types.ModuleType(module_name)
    mod.config = lambda: cfg
    mod.reduced = lambda: cfg
    sys.modules[module_name] = mod

    n_params = cfg.total_params()
    print(f"training {cfg.name}: ~{n_params/1e6:.0f}M params, {run_kw['steps']} steps")
    losses = train(
        cfg.name,
        ckpt_dir=args.ckpt_dir,
        resume=args.resume,
        log_every=5,
        **run_kw,
    )
    print(f"\nloss {losses[0]:.3f} → {losses[-1]:.3f} over {len(losses)} steps")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
