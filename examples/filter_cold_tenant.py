"""Runtime filter install: cache-sensing filters demote a thrashing tenant.

One checked-in policy (``examples/policies/filter_cold_tenant.json``)
installs, onto an already-running 3-process fleet and with zero restarts,

* a ``content_cache`` filter + a ``compression`` filter on every member's
  ``cold`` channel (the filter plane: versioned enforcement code shipped
  over the control plane as housekeeping rules), and
* a trigger on the metric those filters *create*:
  ``cache.hit_rate@cold < 0.3`` — when the cold tenant stops re-reading its
  working set the fleet-merged hit rate collapses, and the trigger demotes
  the tenant's DRLs to the 5 MiB/s floor until locality returns.

The run drives three phases of cold-tenant traffic — re-read a small
working set (hits), thrash with never-repeating payloads (misses), then
re-read again — and verifies everything off the Prometheus scrape
endpoint, exactly as an operator would:

1. the filter chain is live on every member (``stage_info`` shows it) and
   ``paio_trigger_fired`` is pre-registered at 0,
2. ``paio_filter_cache_hit_rate`` for the fleet view breaches 0.3 during
   the thrash phase, the trigger fires, and cold's fleet throughput
   collapses toward the demote floor,
3. locality returns, the hit rate recovers past the hysteresis point, and
   the trigger releases (fired back to 0).

Run: PYTHONPATH=src python examples/filter_cold_tenant.py [--stages 3]
     [--seconds 9]
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MiB = float(1 << 20)
POLICY_FILE = os.path.join(os.path.dirname(__file__), "policies", "filter_cold_tenant.json")

THRASH_START = 2.5  # cold tenant loses locality, seconds after channel birth
THRASH_END = 5.5
PAYLOAD = 16 * 1024  # bytes per cold-tenant read


def _stage_process(name: str, socket_path: str, seconds: float) -> None:
    """One storage-server process. The cold tenant re-reads a 64-payload
    working set (cache hits) except during the thrash window, where every
    read is a never-seen payload (pure misses); the hot tenant is steady
    background traffic that must keep flowing through it all."""
    from repro.core import RequestType, Stage, StageServer, build_context, propagate_tenant

    stage = Stage(name)
    server = StageServer(stage, socket_path).start()
    deadline = time.monotonic() + seconds

    def drive_cold() -> None:
        while stage.channel("cold") is None:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)
        born = time.monotonic()
        with propagate_tenant("cold"):
            ctx = build_context(RequestType.read, size=PAYLOAD)
        working_set = [
            (f"{name}:{i}".encode() * PAYLOAD)[:PAYLOAD] for i in range(64)
        ]
        unique = 0
        i = 0
        while time.monotonic() < deadline:
            t = time.monotonic() - born
            if THRASH_START < t < THRASH_END:
                unique += 1  # locality lost: every payload is new
                payload = (f"{name}:u{unique}".encode() * PAYLOAD)[:PAYLOAD]
            else:
                payload = working_set[i % len(working_set)]
                i += 1
            stage.enforce(ctx, payload)

    def drive_hot() -> None:
        while stage.channel("hot") is None:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)
        with propagate_tenant("hot"):
            ctx = build_context(RequestType.read, size=PAYLOAD)
        while time.monotonic() < deadline:
            stage.enforce(ctx, None)

    threads = [
        threading.Thread(target=drive_cold, daemon=True),
        threading.Thread(target=drive_hot, daemon=True),
    ]
    for t in threads:
        t.start()
    while time.monotonic() < deadline:
        time.sleep(0.1)
    server.stop()


def _fleet_hit_rate(vals) -> float:
    from repro.telemetry import parse_labels

    for series, v in vals.items():
        fam, labels = parse_labels(series)
        if (
            fam == "paio_filter_cache_hit_rate"
            and labels.get("stage") == "@fleet"
            and labels.get("channel") == "cold"
        ):
            return v
    return float("nan")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=3, help="fleet size (stage server processes)")
    ap.add_argument("--seconds", type=float, default=9.0, help="traffic duration per stage process")
    args = ap.parse_args()

    from repro.core import ControlPlane
    from repro.telemetry import parse_prometheus

    stage_names = [f"s{i+1}" for i in range(args.stages)]
    mp = multiprocessing.get_context(
        "fork" if "fork" in multiprocessing.get_all_start_methods() else None
    )
    timeline = []  # (t, fired, fleet_hit_rate, fleet_tput_cold)
    with tempfile.TemporaryDirectory() as sock_dir, ControlPlane(loop_interval=0.05) as cp:
        procs = []
        for name in stage_names:
            path = os.path.join(sock_dir, f"{name}.sock")
            p = mp.Process(
                target=_stage_process, args=(name, path, args.seconds + 5.0), daemon=True
            )
            p.start()
            procs.append((name, path, p))
        for name, path, _ in procs:
            t0 = time.monotonic()
            while not os.path.exists(path):
                if time.monotonic() - t0 > 10.0:
                    raise SystemExit(f"stage {name} never opened {path}")
                time.sleep(0.01)
            cp.connect(name, path)

        # the fleet is live and serving; THIS is the runtime install — no
        # member restarts, the filter chain appears on the next enforce call
        cp.install_policy(POLICY_FILE)
        exporter = cp.serve_metrics()
        print(f"policy + filters installed on {len(stage_names)} live stages; "
              f"exporter on {exporter.url}")

        from repro.transport import RemoteStageHandle

        for name, path, _p in procs:
            handle = RemoteStageHandle(path)
            try:
                info = handle.stage_info()
            finally:
                handle.close()
            filters = info["channels"]["cold"]["filters"]
            if set(filters) != {"content_cache", "compression"}:
                print(f"FAIL: {name} missing filter chain: {sorted(filters)}", file=sys.stderr)
                return 1
            if filters["content_cache"]["capacity"] != 512:
                print(f"FAIL: {name} filter params not applied: {filters}", file=sys.stderr)
                return 1
        print("filter chain live on every member: [content_cache(capacity=512), compression]")

        with urllib.request.urlopen(exporter.url, timeout=5.0) as resp:
            vals = parse_prometheus(resp.read().decode())
        fired_keys = [k for k in vals if k.startswith("paio_trigger_fired")]
        if not fired_keys or any(vals[k] != 0.0 for k in fired_keys):
            print(f"FAIL: trigger not pre-registered at zero: {fired_keys}", file=sys.stderr)
            return 1
        (fired_key,) = fired_keys

        cp.start()
        t0 = time.monotonic()
        deadline = t0 + args.seconds + 6.0
        released_after_fire = False
        while time.monotonic() < deadline:
            time.sleep(0.2)
            with urllib.request.urlopen(exporter.url, timeout=5.0) as resp:
                vals = parse_prometheus(resp.read().decode())
            fired = vals.get(fired_key, 0.0)
            timeline.append(
                (
                    time.monotonic() - t0,
                    fired,
                    _fleet_hit_rate(vals),
                    vals.get('paio_fleet_throughput{flow="cold"}', 0.0),
                )
            )
            if fired == 0.0 and any(s[1] == 1.0 for s in timeline):
                released_after_fire = True
                break
        cp.stop()
        for _, _, p in procs:
            p.terminate()
            p.join(timeout=10.0)

    fire_idx = next((i for i, s in enumerate(timeline) if s[1] == 1.0), None)
    pre = timeline[:fire_idx] if fire_idx is not None else timeline
    during = [s for s in timeline if s[1] == 1.0]
    failures = []
    if not pre:
        failures.append("no armed samples before the thrash phase")
    if not during:
        failures.append("cache.hit_rate trigger never fired under the thrash phase")
    if not released_after_fire:
        failures.append("trigger never released after locality returned")
    if pre:
        warm = [s[2] for s in pre if s[2] == s[2] and s[0] > 1.5]  # skip warmup, NaNs
        if warm and min(warm) < 0.5:
            failures.append(f"hit rate collapsed before the thrash phase: {min(warm):.2f}")
    if during:
        floor_rate = min(s[2] for s in during if s[2] == s[2])
        if not floor_rate < 0.3:
            failures.append(f"fired but scraped fleet hit rate never breached ({floor_rate:.2f})")
    if pre and during:
        settled = [s for s in pre if s[0] > 1.5] or pre
        cold_before = sum(s[3] for s in settled) / len(settled)
        cold_during = min(s[3] for s in during)
        if cold_before > 0 and cold_during >= 0.7 * cold_before:
            failures.append(
                f"demote did not re-weight cold: {cold_before / MiB:.1f} -> "
                f"{cold_during / MiB:.1f} MiB/s"
            )
        else:
            print(
                f"cold demoted under the miss storm: {cold_before / MiB:.1f} -> "
                f"{cold_during / MiB:.1f} MiB/s aggregate; fleet hit rate floor "
                f"{min(s[2] for s in during if s[2] == s[2]):.2f}"
            )

    for f in failures:
        print(f"filter_cold_tenant FAIL: {f}", file=sys.stderr)
    if failures:
        return 1
    fire_at = next(s[0] for s in timeline if s[1] == 1.0)
    release_at = next(s[0] for s in timeline if s[1] == 0.0 and s[0] > fire_at)
    print(
        f"filter plane OK: runtime install, fired at t={fire_at:.1f}s on the miss storm, "
        f"released at t={release_at:.1f}s ({len(timeline)} scrapes)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
