"""Sharded logical stage: flow-hash router, global fair share, kill -9 demo.

A single Python stage process tops out around one core (ROADMAP item 1), so
one *logical* stage is spread over N local ``StageServer`` shard processes
and a :class:`~repro.distributed.ShardRouter` presents them as one stage
again: requests hash by flow (rendezvous/HRW), each flow lives on exactly one
shard, and the checked-in ``examples/policies/sharded_fairshare.json`` policy
declares ``shards: 3`` so its three ``scope: global`` tenant flows bind to
the shard stages ``web/0 … web/2`` — the control plane max-min-shares the
capacity across tenants and splits each tenant's grant across the shards by
measured throughput, so a flow's grant concentrates on its owner shard.

The run then kill -9's the shard owning ``tenant_a``'s flow mid-traffic and
asserts the failover story end to end:

1. the enforce call in flight when the shard dies completes — the router
   re-homes exactly the dead shard's flows to their new HRW owners;
2. the fair share re-converges onto the survivors within ``--tolerance``;
3. after the shard restarts, the control plane replays its deferred rules,
   the router's readmit gate lets it back in only once replay drained, and
   the flow re-homes back to its original owner with the full-fleet fair
   share restored.

Exit 1 if any phase misses its tolerance — usable as a smoke gate.

Run: PYTHONPATH=src python examples/sharded_fairshare.py [--shards 3]
     [--seconds 8] [--tolerance 0.02]
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import sys
import tempfile
import time
from typing import Dict

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MiB = float(1 << 20)
POLICY_FILE = os.path.join(
    os.path.dirname(__file__), "policies", "sharded_fairshare.json"
)
DEMANDS = {"tenant_a": 60 * MiB, "tenant_b": 40 * MiB, "tenant_c": 20 * MiB}


def _serve_shard(name: str, socket_path: str, seconds: float) -> None:
    """One shard process: a plain Stage behind the UDS transport. The shard
    id on the server makes misrouted enforce batches a loud error."""
    from repro.core import Stage
    from repro.transport.server import StageServer

    StageServer(Stage(name), socket_path, shard_id=name).start()
    time.sleep(seconds + 30.0)


def _spawn(mp, name: str, path: str, seconds: float, children: Dict) -> None:
    if os.path.exists(path):
        os.unlink(path)  # stale socket left by a kill -9
    child = mp.Process(target=_serve_shard, args=(name, path, seconds), daemon=True)
    child.start()
    children[name] = child
    t0 = time.monotonic()
    while not os.path.exists(path):
        if time.monotonic() - t0 > 10.0:
            raise RuntimeError(f"shard {name} never bound {path}")
        time.sleep(0.01)


def _grant_sums(router) -> Dict[str, float]:
    """Per-tenant DRL rate summed over live shards — split_flow_rate
    preserves each flow's total grant across its members."""
    sums = {t: 0.0 for t in DEMANDS}
    for info in router.stage_info()["shards"].values():
        for tenant in sums:
            obj = ((info.get("channels") or {}).get(tenant) or {}).get("objects", {})
            if "0" in obj:
                sums[tenant] += obj["0"]["rate"]
    return sums


def _fair(sums: Dict[str, float], tolerance: float) -> bool:
    return all(abs(sums[t] - d) <= tolerance * d for t, d in DEMANDS.items())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shards", type=int, default=3)
    ap.add_argument("--seconds", type=float, default=8.0)
    ap.add_argument("--tolerance", type=float, default=0.02)
    args = ap.parse_args()

    import json

    from repro.core import Context, ControlPlane, RequestType
    from repro.distributed import ShardRouter

    with open(POLICY_FILE) as f:
        policy = json.load(f)
    policy["shards"] = args.shards

    mp = multiprocessing.get_context("fork")
    children: Dict = {}
    exit_code = 0
    with tempfile.TemporaryDirectory() as d:
        paths = [f"{d}/web{i}.sock" for i in range(args.shards)]
        for i in range(args.shards):
            _spawn(mp, f"web/{i}", paths[i], args.seconds, children)
        cp = ControlPlane(probe_interval=0.05)
        router = None
        try:
            names = cp.connect_sharded("web", paths)
            cp.install_policy(policy)
            router = ShardRouter.connect_all(
                "web",
                paths,
                probe_interval=0.05,
                readmit_gate=lambda sid: (
                    cp.stage_up(sid) and cp.fleet_status()[sid]["deferred_rules"] == 0
                ),
            )
            ctxs = [
                Context(0, RequestType.write, 64 << 10, tenant=t)
                for t in DEMANDS
                for _ in range(8)
            ]

            def tick() -> None:
                router.enforce_batch(ctxs)
                cp.run_once()

            def converge(label: str, deadline_s: float, check) -> bool:
                deadline = time.monotonic() + deadline_s
                while time.monotonic() < deadline:
                    tick()
                    if check():
                        print(f"  {label}: ok ({_fmt(_grant_sums(router))})")
                        return True
                    time.sleep(0.02)
                print(f"  {label}: FAILED ({_fmt(_grant_sums(router))})", file=sys.stderr)
                return False

            def _fmt(sums: Dict[str, float]) -> str:
                return ", ".join(f"{t}={v / MiB:.1f}MiB/s" for t, v in sums.items())

            print(f"[1/4] {len(names)} shards up, policy installed; converging fair share")
            if not converge("fair share", args.seconds, lambda: _fair(_grant_sums(router), args.tolerance)):
                return 1

            ctx_a = Context(0, RequestType.write, 64 << 10, tenant="tenant_a")
            victim = router.owner_of(ctx_a)
            print(f"[2/4] kill -9 {victim} (owner of tenant_a's flow), mid-traffic")
            children[victim].kill()
            children[victim].join(timeout=10.0)
            results = router.enforce_batch(ctxs)
            assert len(results) == len(ctxs), "enforce lost requests across the death"
            print(
                f"  re-homed: tenant_a now on {router.owner_of(ctx_a)}, "
                f"failovers={router.failovers}, live={list(router.shards)}"
            )

            print(f"[3/4] converging survivor fair share (tolerance {args.tolerance:.0%})")
            if not converge(
                "survivor fair share",
                args.seconds,
                lambda: not cp.stage_up(victim) and _fair(_grant_sums(router), args.tolerance),
            ):
                return 1

            print(f"[4/4] restart {victim}; waiting for replay + readmit")
            _spawn(mp, victim, paths[int(victim.split("/")[1])], args.seconds, children)
            ok = converge(
                "recovery",
                args.seconds + 10.0,
                lambda: (
                    cp.stage_up(victim)
                    and cp.fleet_status()[victim]["deferred_rules"] == 0
                    and victim in router.shards
                    and router.owner_of(ctx_a) == victim
                    and _fair(_grant_sums(router), args.tolerance)
                ),
            )
            if not ok:
                return 1
            deferred = sum(s["deferred_rules"] for s in cp.fleet_status().values())
            print(f"PASS: zero deferred rules fleet-wide ({deferred}), flow back on {victim}")
        finally:
            if router is not None:
                router.close()
            cp.close()
            for child in children.values():
                if child.is_alive():
                    child.kill()
    return exit_code


if __name__ == "__main__":
    sys.exit(main())
