"""Quickstart: PAIO data plane + a tiny transformer in ~60 lines.

Builds a stage with foreground/background channels, trains a reduced
llama-style model for a few steps with the input pipeline flowing through the
stage, checkpoints through a DRL-limited background channel, and prints the
per-flow I/O statistics the control plane would consume.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

from repro.launch.train import train


def main() -> None:
    with tempfile.TemporaryDirectory() as ckpt_dir:
        losses = train(
            "llama3_2_1b",
            reduced=True,  # smoke-scale config (the full 1.24B needs a pod)
            steps=12,
            batch=8,
            seq=64,
            ckpt_dir=ckpt_dir,
            ckpt_every=5,
        )
    assert losses[-1] < losses[0], "loss should decrease"
    print(f"\nquickstart OK: loss {losses[0]:.3f} → {losses[-1]:.3f}")


if __name__ == "__main__":
    main()
