"""Fleet-scale fair share: one control plane, many stage *processes*, one SLO.

The paper's use case 2 (per-application bandwidth guarantees) at fleet
topology: N storage-server processes each embed a PAIO stage served over the
UDS transport; every tenant's traffic lands on *all* of them. One control
plane connects to the whole fleet and installs the checked-in
``examples/policies/fleet_fairshare.json`` policy — three ``scope: global``
flows (one per tenant) and a fair-share objective whose per-tenant demands are
guaranteed in **aggregate** across the fleet: each control tick collects every
stage concurrently, max-min-shares the global capacity across tenants, and
splits each tenant's grant across its per-stage DRLs by measured throughput.

The run asserts every tenant's steady-state aggregate bandwidth meets its
demand within ``--tolerance`` (exit 1 otherwise) — the CI gate for the
fleet control loop. With ``--export`` it also serves the Prometheus endpoint
and scrapes itself to assert ``paio_stage_up`` is 1 for every stage.

Run: PYTHONPATH=src python examples/fleet_fairshare.py [--stages 3]
     [--seconds 6] [--scale 1.0] [--export PORT]
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import sys
import tempfile
import threading
import time
from typing import Dict, List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MiB = float(1 << 20)
POLICY_FILE = os.path.join(os.path.dirname(__file__), "policies", "fleet_fairshare.json")


def _stage_process(name: str, socket_path: str, tenants: List[str], seconds: float, chunk: int) -> None:
    """One storage-server process: a Stage behind the UDS transport, with a
    greedy driver thread per tenant (offered load is unconstrained — the
    policy's DRLs are the only thing shaping it)."""
    from repro.core import RequestType, Stage, StageServer, build_context, propagate_tenant

    stage = Stage(name)
    server = StageServer(stage, socket_path).start()
    deadline = time.monotonic() + seconds

    def drive(tenant: str) -> None:
        # wait for the policy to provision this tenant's channel — free-running
        # through the default channel would just burn CPU before install
        while stage.channel(tenant) is None:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)
        with propagate_tenant(tenant):
            ctx = build_context(RequestType.read, size=chunk)
        while time.monotonic() < deadline:
            stage.enforce(ctx, None)

    threads = [threading.Thread(target=drive, args=(t,), daemon=True) for t in tenants]
    for t in threads:
        t.start()
    while time.monotonic() < deadline:
        time.sleep(0.1)
    server.stop()


def _tenant_rates_per_tick(history, stages: List[str], tenants: List[str]) -> List[Dict[str, float]]:
    """Per-control-tick aggregate bandwidth per tenant (sum of member
    channel throughputs across the fleet)."""
    out = []
    for entry in history:
        rates = {t: 0.0 for t in tenants}
        for stage in stages:
            st = entry.get(stage)
            if st is None:
                continue
            for tenant in tenants:
                snap = st.per_channel.get(tenant)
                if snap is not None:
                    rates[tenant] += snap.throughput
        out.append(rates)
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=3, help="fleet size (stage server processes)")
    ap.add_argument("--seconds", type=float, default=6.0, help="traffic duration per stage process")
    ap.add_argument("--scale", type=float, default=1.0, help="scale every policy bandwidth by this factor")
    ap.add_argument("--chunk", type=int, default=128 * 1024, help="bytes per enforced request")
    ap.add_argument("--tolerance", type=float, default=0.05, help="allowed per-tenant guarantee shortfall")
    ap.add_argument("--warmup", type=float, default=0.35, help="fraction of ticks discarded as warmup")
    ap.add_argument(
        "--export", type=int, default=None, metavar="PORT",
        help="serve Prometheus metrics during the run and self-scrape paio_stage_up "
        "for every stage (0 binds an ephemeral port)",
    )
    args = ap.parse_args()

    from benchmarks.bench_bandwidth_fairshare import _scaled_policy
    from repro.core import ControlPlane

    policy = _scaled_policy(POLICY_FILE, args.scale)
    tenants = [f.name for f in policy.flows]
    demands = {
        name: float(qty) for name, qty in dict(dict(policy.objective.params)["demands"]).items()
    }
    stage_names = [f"s{i+1}" for i in range(args.stages)]

    mp = multiprocessing.get_context("fork" if "fork" in multiprocessing.get_all_start_methods() else None)
    with tempfile.TemporaryDirectory() as sock_dir, ControlPlane(loop_interval=0.05) as cp:
        procs = []
        for name in stage_names:
            path = os.path.join(sock_dir, f"{name}.sock")
            # children outlive the measurement window: the parent decides when
            # the run ends (stop + terminate), so child exit never races the
            # final collect ticks or the self-scrape
            p = mp.Process(
                target=_stage_process,
                args=(name, path, tenants, args.seconds + 5.0, args.chunk),
                daemon=True,
            )
            p.start()
            procs.append((name, path, p))
        for name, path, _ in procs:
            t0 = time.monotonic()
            while not os.path.exists(path):
                if time.monotonic() - t0 > 10.0:
                    raise SystemExit(f"stage {name} never opened {path}")
                time.sleep(0.01)
            cp.connect(name, path)

        cp.install_policy(policy)
        cp.keep_history = True
        exporter = cp.serve_metrics(port=args.export) if args.export is not None else None
        if exporter is not None:
            print(f"metrics exporter listening on {exporter.url}")
        cp.start()
        time.sleep(max(args.seconds - 1.0, 1.0))  # the measurement window

        stage_up_ok = True
        if exporter is not None:
            import urllib.request

            from repro.telemetry import parse_prometheus

            with urllib.request.urlopen(exporter.url, timeout=5.0) as resp:
                metrics = parse_prometheus(resp.read().decode())
            for name in stage_names:
                key = f'paio_stage_up{{stage="{name}"}}'
                if metrics.get(key) != 1.0:
                    print(f"FAIL: {key} = {metrics.get(key)!r} (expected 1)")
                    stage_up_ok = False
            if stage_up_ok:
                print(f"paio_stage_up == 1 for all {len(stage_names)} stages (self-scraped)")

        cp.stop()
        per_tick = _tenant_rates_per_tick(cp.history, stage_names, tenants)
        for _, _, p in procs:
            p.terminate()
            p.join(timeout=10.0)

    if not per_tick:
        raise SystemExit("control loop produced no history")
    steady = per_tick[int(len(per_tick) * args.warmup):]
    achieved = {
        t: sum(r[t] for r in steady) / len(steady) for t in tenants
    }
    # convergence: first tick from which every tenant holds >= 90% of demand
    # for 5 consecutive ticks
    converged_tick = None
    for i in range(len(per_tick) - 5):
        if all(
            all(per_tick[i + k][t] >= demands[t] * 0.9 for t in tenants) for k in range(5)
        ):
            converged_tick = i
            break

    capacity = sum(demands.values())
    print(
        f"\nfleet: {len(stage_names)} stage processes over UDS; "
        f"capacity {capacity / MiB:.0f} MiB/s; {len(per_tick)} control ticks"
    )
    print(f"{'tenant':<10} {'demand MiB/s':>12} {'achieved MiB/s':>15} {'met':>6}")
    violations = []
    for t in tenants:
        ok = achieved[t] >= demands[t] * (1.0 - args.tolerance)
        if not ok:
            violations.append(t)
        print(f"{t:<10} {demands[t]/MiB:>12.1f} {achieved[t]/MiB:>15.1f} {'yes' if ok else 'NO':>6}")
    if converged_tick is not None:
        print(f"converged (all tenants >= 90% of demand, 5 ticks) by tick {converged_tick} "
              f"(~{converged_tick * 0.05:.2f}s after loop start)")
    else:
        print("WARNING: no 5-tick convergence window found")
    if violations:
        print(f"FAIL: guarantees violated for {violations}")
        return 1
    if not stage_up_ok:
        return 1
    print("all per-tenant guarantees met across the fleet")
    return 0


if __name__ == "__main__":
    sys.exit(main())
