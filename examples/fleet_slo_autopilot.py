"""Fleet SLO autopilot: cluster-scoped triggers re-weighting shards.

One checked-in policy (``examples/policies/fleet_slo_autopilot.json``)
declares both halves of a per-tenant SLO over a 3-process fleet:

* **bandwidth** — a fair-share objective guaranteeing ``frontend`` 60 MiB/s
  and ``batch`` 40 MiB/s in aggregate across every stage process, and
* **tail latency** — a ``@fleet.p99`` trigger: when the p99 of frontend
  waits *merged across every member's histogram* breaches 25 ms, demote the
  batch flow fleet-wide (its DRLs drop to the 5 MiB/s demote floor) until
  the tail clears.

The run injects a latency hotspot on ONE member's frontend shard — every
other member stays fast, so only the fleet-merged histogram sees the SLO
breach (each healthy member's own p99 never moves). Everything is verified
off the Prometheus scrape endpoint, exactly as an operator would see it:

1. before the hotspot: ``paio_trigger_fired`` is 0 (pre-registered at zero),
2. under the hotspot: fired flips to 1, ``paio_fleet_wait_p99_ms`` breaches,
   and batch's fleet throughput collapses to the demote floor,
3. after the hotspot: the trigger releases and batch recovers, and
4. the merged fleet histogram renders as a valid native Prometheus family
   (cumulative ``_bucket`` rows non-decreasing, ``+Inf`` row == ``_count``).

Run: PYTHONPATH=src python examples/fleet_slo_autopilot.py [--stages 3]
     [--seconds 9]
"""
from __future__ import annotations

import argparse
import multiprocessing
import os
import sys
import tempfile
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

MiB = float(1 << 20)
POLICY_FILE = os.path.join(os.path.dirname(__file__), "policies", "fleet_slo_autopilot.json")

HOT_START = 2.5  # hotspot window, seconds after the member's channels appear
HOT_END = 5.5


def _stage_process(name: str, socket_path: str, seconds: float, hot: bool) -> None:
    """One storage-server process: greedy enforce-driven traffic on both
    tenants (the policy's DRLs are the only thing shaping it). A ``hot``
    member also injects 100 ms service-latency observations into its
    frontend shard between HOT_START and HOT_END — the synthetic hotspot."""
    from repro.core import RequestType, Stage, StageServer, build_context, propagate_tenant

    stage = Stage(name)
    server = StageServer(stage, socket_path).start()
    deadline = time.monotonic() + seconds

    def drive(tenant: str) -> None:
        while stage.channel(tenant) is None:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)
        with propagate_tenant(tenant):
            ctx = build_context(RequestType.read, size=64 * 1024)
        while time.monotonic() < deadline:
            stage.enforce(ctx, None)

    def inject_hotspot() -> None:
        while stage.channel("frontend") is None:
            if time.monotonic() >= deadline:
                return
            time.sleep(0.01)
        born = time.monotonic()
        ch = stage.channel("frontend")
        while time.monotonic() < deadline:
            t = time.monotonic() - born
            if HOT_START < t < HOT_END:
                # a slow device/shard: ops completing with 100 ms latency
                ch.stats.record(0, wait=0.1)
            time.sleep(0.005)

    threads = [threading.Thread(target=drive, args=(t,), daemon=True) for t in ("frontend", "batch")]
    if hot:
        threads.append(threading.Thread(target=inject_hotspot, daemon=True))
    for t in threads:
        t.start()
    while time.monotonic() < deadline:
        time.sleep(0.1)
    server.stop()


def _check_histogram(vals, flow: str):
    """Validate the merged fleet histogram family for ``flow`` as rendered:
    cumulative _bucket rows non-decreasing in le, +Inf row == _count > 0."""
    from repro.telemetry import parse_labels

    rows = []
    for series, v in vals.items():
        fam, labels = parse_labels(series)
        if fam == "paio_fleet_wait_hist_ms_bucket" and labels.get("flow") == flow:
            le = labels["le"]
            rows.append((float("inf") if le == "+Inf" else float(le), v))
    rows.sort()
    count = vals.get(f'paio_fleet_wait_hist_ms_count{{flow="{flow}"}}')
    if len(rows) < 2:
        return f"too few _bucket rows for flow={flow!r} ({len(rows)})"
    counts = [v for _, v in rows]
    if counts != sorted(counts):
        return f"non-monotone cumulative _bucket rows for flow={flow!r}: {counts}"
    if rows[-1][0] != float("inf") or rows[-1][1] != count or not count:
        return f"+Inf bucket ({rows[-1][1]}) != _count ({count}) for flow={flow!r}"
    return None


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stages", type=int, default=3, help="fleet size (stage server processes)")
    ap.add_argument("--seconds", type=float, default=9.0, help="traffic duration per stage process")
    args = ap.parse_args()

    from repro.core import ControlPlane
    from repro.telemetry import parse_prometheus

    stage_names = [f"s{i+1}" for i in range(args.stages)]
    mp = multiprocessing.get_context(
        "fork" if "fork" in multiprocessing.get_all_start_methods() else None
    )
    timeline = []  # (t, fired, fleet_p99_frontend, fleet_tput_batch)
    hist_failure = "never scraped a fired sample"
    with tempfile.TemporaryDirectory() as sock_dir, ControlPlane(loop_interval=0.05) as cp:
        procs = []
        for i, name in enumerate(stage_names):
            path = os.path.join(sock_dir, f"{name}.sock")
            p = mp.Process(
                target=_stage_process,
                args=(name, path, args.seconds + 5.0, i == len(stage_names) - 1),
                daemon=True,
            )
            p.start()
            procs.append((name, path, p))
        for name, path, _ in procs:
            t0 = time.monotonic()
            while not os.path.exists(path):
                if time.monotonic() - t0 > 10.0:
                    raise SystemExit(f"stage {name} never opened {path}")
                time.sleep(0.01)
            cp.connect(name, path)

        cp.install_policy(POLICY_FILE)
        exporter = cp.serve_metrics()
        print(f"policy installed on {len(stage_names)} stages; exporter on {exporter.url}")

        # pre-registration: the trigger + fleet families are on the endpoint
        # at zero BEFORE the loop has run a single tick
        with urllib.request.urlopen(exporter.url, timeout=5.0) as resp:
            vals = parse_prometheus(resp.read().decode())
        fired_keys = [k for k in vals if k.startswith("paio_trigger_fired")]
        if not fired_keys or any(vals[k] != 0.0 for k in fired_keys):
            print(f"FAIL: trigger not pre-registered at zero: {fired_keys}", file=sys.stderr)
            return 1
        if vals.get('paio_fleet_wait_p99_ms{flow="frontend"}') != 0.0:
            print("FAIL: paio_fleet_wait_p99_ms not pre-registered at zero", file=sys.stderr)
            return 1
        (fired_key,) = fired_keys
        print(f"pre-registered at zero: {fired_key}, paio_fleet_* families")

        cp.start()
        t0 = time.monotonic()
        deadline = t0 + args.seconds + 6.0
        released_after_fire = False
        while time.monotonic() < deadline:
            time.sleep(0.2)
            with urllib.request.urlopen(exporter.url, timeout=5.0) as resp:
                vals = parse_prometheus(resp.read().decode())
            fired = vals.get(fired_key, 0.0)
            timeline.append(
                (
                    time.monotonic() - t0,
                    fired,
                    vals.get('paio_fleet_wait_p99_ms{flow="frontend"}', 0.0),
                    vals.get('paio_fleet_throughput{flow="batch"}', 0.0),
                )
            )
            if fired == 1.0:
                hist_failure = _check_histogram(vals, "frontend")
            if fired == 0.0 and any(s[1] == 1.0 for s in timeline):
                released_after_fire = True
                break
        cp.stop()
        for _, _, p in procs:
            p.terminate()
            p.join(timeout=10.0)

    pre = [s for s in timeline if s[1] == 0.0 and not any(x[1] == 1.0 for x in timeline[: timeline.index(s)])]
    during = [s for s in timeline if s[1] == 1.0]
    failures = []
    if not pre:
        failures.append("no pre-hotspot samples with the trigger armed")
    if not during:
        failures.append("@fleet.p99 trigger never fired under the injected hotspot")
    if not released_after_fire:
        failures.append("trigger never released after the hotspot cleared")
    if during:
        peak_p99 = max(s[2] for s in during)
        if peak_p99 <= 25.0:
            failures.append(f"fired but scraped fleet p99 never breached the SLO ({peak_p99:.1f} ms)")
        if hist_failure:
            failures.append(f"fleet histogram family invalid: {hist_failure}")
    if pre and during:
        # skip the first second of armed samples: fair-share convergence
        settled = [s for s in pre if s[0] > 1.0] or pre
        batch_before = sum(s[3] for s in settled) / len(settled)
        batch_during = sum(s[3] for s in during) / len(during)
        if batch_before > 0 and batch_during >= 0.7 * batch_before:
            failures.append(
                f"demote did not re-weight the fleet: batch {batch_before / MiB:.1f} -> "
                f"{batch_during / MiB:.1f} MiB/s"
            )
        else:
            print(
                f"batch re-weighted under the breach: {batch_before / MiB:.1f} -> "
                f"{batch_during / MiB:.1f} MiB/s aggregate; "
                f"fleet frontend p99 peaked at {max(s[2] for s in during):.1f} ms"
            )

    for f in failures:
        print(f"slo_autopilot FAIL: {f}", file=sys.stderr)
    if failures:
        return 1
    fire_at = next(s[0] for s in timeline if s[1] == 1.0)
    release_at = next(s[0] for s in timeline if s[1] == 0.0 and s[0] > fire_at)
    print(
        f"SLO autopilot OK: fired at t={fire_at:.1f}s, released at t={release_at:.1f}s; "
        f"merged fleet histogram valid ({len(timeline)} scrapes)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
