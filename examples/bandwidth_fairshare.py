"""The paper's Fig 8 scenario as a runnable example: four training-job
instances with bandwidth guarantees sharing one disk, under baseline /
static-blkio / PAIO max-min fair share.

The PAIO setup is driven entirely by the checked-in policy file
``examples/policies/fairshare.json`` — channels, DRL provisioning,
differentiation and the fair-share objective all come from the policy, not
from code (pass an explicit ``--policy ''`` to fall back to the hand-coded
construction).

Run: PYTHONPATH=src python examples/bandwidth_fairshare.py
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_bandwidth_fairshare import main

if __name__ == "__main__":
    if not any(a.startswith("--policy") for a in sys.argv[1:]):
        sys.argv += ["--policy", os.path.join(os.path.dirname(__file__), "policies", "fairshare.json")]
    main()
