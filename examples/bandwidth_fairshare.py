"""The paper's Fig 8 scenario as a runnable example: four training-job
instances with bandwidth guarantees sharing one disk, under baseline /
static-blkio / PAIO max-min fair share.

Run: PYTHONPATH=src python examples/bandwidth_fairshare.py
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.bench_bandwidth_fairshare import main

if __name__ == "__main__":
    main()
