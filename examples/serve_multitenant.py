"""Multi-tenant serving with per-tenant token-rate policies (paper §5.2
applied to inference).

Two tenants share one model server. Each tenant's requests flow through its
PAIO channel with a DRL object; the control plane (Algorithm 2, max-min fair
share) guarantees tenant A 2× tenant B's token rate and redistributes the
budget when one goes idle.

Run: PYTHONPATH=src python examples/serve_multitenant.py
"""
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax

import repro.configs as configs
from repro.core import (
    ControlPlane,
    DifferentiationRule,
    FairShareControl,
    FlowSpec,
    HousekeepingRule,
    Stage,
)
from repro.models import init_params
from repro.serve import ServeEngine


def main() -> None:
    cfg = configs.get_reduced("llama3_2_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    stage = Stage("serve")
    for tenant in ("tenant_a", "tenant_b"):
        stage.hsk_rule(HousekeepingRule(op="create_channel", channel=tenant))
        stage.hsk_rule(
            HousekeepingRule(
                op="create_object", channel=tenant, object_id="0", object_kind="drl",
                params={"rate": 100.0},  # tokens/s placeholder; control plane retunes
            )
        )
        stage.dif_rule(DifferentiationRule(channel=tenant, match={"tenant": tenant}))

    algo = FairShareControl(
        flows={t: FlowSpec("serve", t) for t in ("tenant_a", "tenant_b")},
        demands={"tenant_a": 400.0, "tenant_b": 200.0},  # tokens/s guarantees
        max_bandwidth=600.0,
        loop_interval=0.1,
    )
    cp = ControlPlane(algo)
    cp.register_stage(stage)
    cp.start()

    engine = ServeEngine(cfg, params, max_seq=64, stage=stage)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)

    for tenant in ("tenant_a", "tenant_b"):
        t0 = time.monotonic()
        results = engine.generate(prompts, max_new_tokens=16, tenant=tenant)
        dt = time.monotonic() - t0
        n_tokens = sum(len(r.tokens) for r in results)
        print(f"{tenant}: {n_tokens} tokens in {dt:.2f}s → {n_tokens/dt:.0f} tok/s "
              f"(DRL rate {stage.channel(tenant).get_object('0').rate:.0f} tok/s)")

    stats = stage.collect()
    for name, snap in stats.per_channel.items():
        if snap.cumulative_ops:
            print(f"channel {name}: ops={snap.cumulative_ops} bytes(tokens)={snap.cumulative_bytes}")
    cp.stop()
    print("serve_multitenant OK")


if __name__ == "__main__":
    main()
