"""Multi-tenant serving with per-tenant token-rate policies (paper §5.2
applied to inference).

Two tenants share one model server. The whole setup — per-tenant channels,
DRL token buckets, differentiation and the max-min fair-share objective
guaranteeing tenant A 2× tenant B's token rate — comes from the checked-in
policy file ``examples/policies/serve_multitenant.json``; this example only
registers the stage and calls ``ControlPlane.install_policy``.

Run: PYTHONPATH=src python examples/serve_multitenant.py [--export PORT]

With ``--export`` the shared metrics exporter serves stage gauges, policy
versions and serve-engine summaries on ``http://127.0.0.1:PORT/metrics``
while the example runs (0 binds an ephemeral port, printed at startup).
"""
import argparse
import sys
import os
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import jax

import repro.configs as configs
from repro.core import ControlPlane, Stage
from repro.models import init_params
from repro.serve import ServeEngine

POLICY_FILE = os.path.join(os.path.dirname(__file__), "policies", "serve_multitenant.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--export", type=int, default=None, metavar="PORT",
                    help="serve Prometheus-text metrics on this port (0 = ephemeral)")
    args = ap.parse_args()

    cfg = configs.get_reduced("llama3_2_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))

    stage = Stage("serve")
    cp = ControlPlane()
    cp.register_stage(stage)
    name = cp.install_policy(POLICY_FILE)
    print(f"installed policy {name!r}: {cp.list_policies()[0]}")
    exporter = cp.serve_metrics(port=args.export) if args.export is not None else None
    if exporter is not None:
        print(f"metrics exporter listening on {exporter.url}")
    cp.start()

    engine = ServeEngine(cfg, params, max_seq=64, stage=stage)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, size=(2, 8)).astype(np.int32)

    for tenant in ("tenant_a", "tenant_b"):
        t0 = time.monotonic()
        results = engine.generate(prompts, max_new_tokens=16, tenant=tenant)
        dt = time.monotonic() - t0
        n_tokens = sum(len(r.tokens) for r in results)
        print(f"{tenant}: {n_tokens} tokens in {dt:.2f}s → {n_tokens/dt:.0f} tok/s "
              f"(DRL rate {stage.channel(tenant).get_object('0').rate:.0f} tok/s)")

    stats = stage.collect()
    for name, snap in stats.per_channel.items():
        if snap.cumulative_ops:
            print(f"channel {name}: ops={snap.cumulative_ops} bytes(tokens)={snap.cumulative_bytes}")
    cp.close()
    if exporter is not None:
        exporter.stop()
    print("serve_multitenant OK")


if __name__ == "__main__":
    main()
